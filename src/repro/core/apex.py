"""The Ape-X loop on a TPU mesh: SPMD actor/learner alternation.

Paper architecture (Fig. 1): many actors feed a shared prioritized replay; a
single learner samples, updates, and writes back priorities; actors refresh
parameters periodically. TPU-native realization (DESIGN.md §2):

* Actor lanes — every ``data``-axis shard steps a vector of environments with
  its slice of the global eps-ladder; the *whole* global lane vector plays the
  role of the paper's N actors (eps_i = eps^(1 + i/(N-1)*alpha) over global
  lane ids).
* Sharded replay — each shard owns ``capacity/num_shards`` slots. Experience
  never crosses shards; the learner's gradient psum and two scalars per
  sampling round (global size, global max-IS-weight) are the only collectives.
* Staleness — actors act with a parameter copy refreshed every
  ``param_sync_period`` iterations (paper: every 400 frames), making the
  off-policy gap explicit and testable.
* Alternation — acting and learning run bulk-synchronously;
  ``learner_steps_per_iter`` and ``rollout_len`` set the paper's generate :
  consume ratio (~12.5K : 9.7K transitions/s in §4.1).

Everything below is per-shard pure functions plus a ``shard_map`` wrapper.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import codec, nstep, priority as prio, replay as replay_lib
from repro.envs.synthetic import batch_reset, batch_step
from repro.optim import optimizers as optim


@dataclasses.dataclass(frozen=True)
class ApexConfig:
    replay: replay_lib.ReplayConfig
    lanes_per_shard: int = 32          # vectorized envs per shard
    num_shards: int = 1                # data-axis size (for the global ladder)
    rollout_len: int = 16              # T env steps per actor phase
    n_step: int = 3                    # paper: n = 3
    batch_size: int = 64               # learner batch per shard
    learner_steps_per_iter: int = 1
    param_sync_period: int = 1         # iterations between actor param refresh
    target_update_period: int = 100    # learner steps (paper Atari: 2500)
    evict_interval: int = 100          # learner steps between evictions (paper: 100)
    evict_num: int = 0                 # victims per prioritized eviction (DPG mode)
    eviction: str = "fifo"             # "fifo" | "prioritized"
    replicate_k: int = 1               # Fig. 6 ablation: add each transition k times
    eps_mode: str = "ladder"           # "ladder" | "fixed_set" (Fig. 7 ablation)
    eps_base: float = prio.EPSILON_BASE
    eps_alpha: float = prio.EPSILON_ALPHA
    compress_obs: bool = False         # store obs via the uint8 codec (the
                                       # paper's PNG-compression analogue)

    @property
    def num_actors(self) -> int:
        return self.lanes_per_shard * self.num_shards

    @property
    def window(self) -> int:
        return self.rollout_len - self.n_step + 1


class ApexState(NamedTuple):
    # replicated across shards
    params: Any
    target_params: Any
    opt_state: Any
    actor_params: Any          # the stale copy actors act with
    iteration: jax.Array
    learner_step: jax.Array
    # per-shard
    replay: replay_lib.ReplayState
    env_state: Any             # (lanes, ...)
    obs: jax.Array             # (lanes, ...)
    ep_return: jax.Array       # (lanes,) running episode return
    rng: jax.Array
    frames: jax.Array          # env steps on this shard


REPLICATED_FIELDS = ("params", "target_params", "opt_state", "actor_params",
                     "iteration", "learner_step")


def lane_epsilons(cfg: ApexConfig, shard_id: jax.Array) -> jax.Array:
    """This shard's slice of the global exploration ladder."""
    if cfg.eps_mode == "ladder":
        table = prio.epsilon_ladder(cfg.num_actors, cfg.eps_base, cfg.eps_alpha)
    elif cfg.eps_mode == "fixed_set":
        table = prio.fixed_epsilon_set(cfg.num_actors)
    else:
        raise ValueError(cfg.eps_mode)
    gids = shard_id * cfg.lanes_per_shard + jnp.arange(cfg.lanes_per_shard)
    return table[gids]


def init_state(cfg: ApexConfig, env, agent, optimizer, rng: jax.Array,
               shard_id: jax.Array | int = 0) -> ApexState:
    rng = jax.random.fold_in(rng, jnp.asarray(shard_id))
    p_rng, e_rng, s_rng = jax.random.split(rng, 3)
    env_state, obs = batch_reset(env, e_rng, cfg.lanes_per_shard)
    params = agent.init(p_rng, obs[:1])
    item = _item_example(env, obs, cfg.compress_obs)
    return ApexState(
        params=params,
        target_params=jax.tree.map(jnp.copy, params),
        opt_state=optimizer.init(params),
        actor_params=jax.tree.map(jnp.copy, params),
        iteration=jnp.zeros((), jnp.int32),
        learner_step=jnp.zeros((), jnp.int32),
        replay=replay_lib.init(cfg.replay, item),
        env_state=env_state,
        obs=obs,
        ep_return=jnp.zeros((cfg.lanes_per_shard,), jnp.float32),
        rng=s_rng,
        frames=jnp.zeros((), jnp.int32),
    )


def _item_example(env, obs: jax.Array, compress: bool = False) -> dict:
    """Replay item: the paper stores both endpoint states per transition
    ("costs more RAM, but simplifies the code" — Appendix F)."""
    ob = obs[0]
    if compress:
        ob = codec.encode(ob[None])._asdict()
        ob = {k: v[0] for k, v in ob.items()}
    if hasattr(env, "num_actions"):
        action = jnp.zeros((), jnp.int32)
    else:
        action = jnp.zeros((env.action_dim,), jnp.float32)
    return {
        "obs": ob, "action": action,
        "returns": jnp.zeros((), jnp.float32),
        "discount_n": jnp.zeros((), jnp.float32),
        "next_obs": ob,
    }


# ---------------------------------------------------------------------------
# Actor phase
# ---------------------------------------------------------------------------

def actor_phase(cfg: ApexConfig, env, agent, state: ApexState,
                shard_id: jax.Array | int = 0) -> tuple[ApexState, dict]:
    """Roll out T steps per lane, build n-step transitions from the trajectory,
    compute initial priorities from the buffered Q-values, bulk-add to the
    shard's replay slots (Alg. 1, vectorized)."""
    eps = lane_epsilons(cfg, jnp.asarray(shard_id))
    rng, rollout_rng, last_rng = jax.random.split(state.rng, 3)
    step_rngs = jax.random.split(rollout_rng, cfg.rollout_len)

    def step_fn(carry, rng_t):
        env_state, obs, ep_ret = carry
        a, aux = agent.act(state.actor_params, rng_t, obs, eps)
        env_state, out = batch_step(env, env_state, a)
        done = out.discount == 0.0
        ep_ret_next = jnp.where(done, 0.0, ep_ret + out.reward)
        completed = jnp.where(done, ep_ret + out.reward, jnp.nan)
        emit = dict(obs=obs, action=a, aux=aux, reward=out.reward,
                    discount=out.discount, completed=completed)
        return (env_state, out.obs, ep_ret_next), emit

    (env_state, last_obs, ep_ret), traj = jax.lax.scan(
        step_fn, (state.env_state, state.obs, state.ep_return), step_rngs)
    # time-major (T, lanes, ...) -> lane-major (lanes, T, ...)
    traj = jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1), traj)

    # Bootstrap aux at the final state S_T (one extra policy eval).
    _, last_aux = agent.act(state.actor_params, last_rng, last_obs, eps)

    n, T, W = cfg.n_step, cfg.rollout_len, cfg.window
    returns, discount_n = nstep.from_trajectory(traj["reward"], traj["discount"], n)

    full_obs = jnp.concatenate([traj["obs"], last_obs[:, None]], axis=1)  # (lanes, T+1, ...)
    full_aux = jax.tree.map(
        lambda a, b: jnp.concatenate([a, b[:, None]], axis=1), traj["aux"], last_aux)

    first_aux = jax.tree.map(lambda x: x[:, :W], full_aux)
    last_aux_w = jax.tree.map(lambda x: x[:, n:], full_aux)
    action_w = traj["action"][:, :W]
    priorities = agent.initial_priorities(
        *jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]),
                      (first_aux, action_w, returns, discount_n, last_aux_w)))

    flat = lambda x: x.reshape((-1,) + x.shape[2:])
    enc = ((lambda o: dict(codec.encode(o)._asdict())) if cfg.compress_obs
           else (lambda o: o))
    items = {
        "obs": enc(flat(full_obs[:, :W])),
        "action": flat(action_w),
        "returns": flat(returns),
        "discount_n": flat(discount_n),
        "next_obs": enc(flat(full_obs[:, n:])),
    }
    if cfg.replicate_k > 1:  # Fig. 6 recency-vs-diversity ablation
        items = jax.tree.map(lambda x: jnp.tile(x, (cfg.replicate_k,) + (1,) * (x.ndim - 1)), items)
        priorities = jnp.tile(priorities, cfg.replicate_k)

    add = replay_lib.add_fifo if cfg.eviction == "fifo" else replay_lib.add_alloc
    new_replay = add(cfg.replay, state.replay, items, priorities)

    completed = traj["completed"]
    n_done = jnp.sum(~jnp.isnan(completed))
    mean_ep_return = jnp.where(
        n_done > 0, jnp.nansum(completed) / jnp.maximum(n_done, 1), jnp.nan)
    metrics = {"mean_ep_return": mean_ep_return, "episodes": n_done,
               "mean_initial_priority": priorities.mean()}

    state = state._replace(
        replay=new_replay, env_state=env_state, obs=last_obs, ep_return=ep_ret,
        rng=rng, frames=state.frames + cfg.lanes_per_shard * cfg.rollout_len)
    return state, metrics


# ---------------------------------------------------------------------------
# Learner phase
# ---------------------------------------------------------------------------

def _global_is_weights(cfg: ApexConfig, batch: replay_lib.SampleBatch,
                       size: jax.Array, axis_name: str | None) -> jax.Array:
    """IS weights for the *actual* global sampling distribution.

    With equal per-shard quotas, P(i) = leaf_i / (shard_total * num_shards);
    correcting with the global N and global max keeps the estimate unbiased
    even when shard masses drift apart. Two scalar collectives total.
    """
    if axis_name is None:
        return batch.is_weights
    n_global = jax.lax.psum(size, axis_name)
    p = batch.leaf_mass / jnp.maximum(batch.total_mass * cfg.num_shards, 1e-30)
    w = jnp.power(jnp.maximum(n_global.astype(jnp.float32), 1.0)
                  * jnp.maximum(p, 1e-30), -cfg.replay.beta)
    w_max = jax.lax.pmax(jnp.max(w), axis_name)
    return w / jnp.maximum(w_max, 1e-30)


def learner_phase(cfg: ApexConfig, agent, optimizer, state: ApexState,
                  axis_name: str | None = None) -> tuple[ApexState, dict]:
    """Sample prioritized batches, apply the off-policy update, write back
    fresh priorities, periodically update the target net and evict (Alg. 2)."""
    rcfg = cfg.replay

    def one_step(st: ApexState, rng: jax.Array) -> tuple[ApexState, dict]:
        ready = replay_lib.can_sample(rcfg, st.replay)
        if axis_name is not None:
            # learner starts only when every shard passed min-fill (paper: a
            # single global threshold of 50000 transitions).
            ready = jax.lax.pmin(ready.astype(jnp.int32), axis_name) > 0

        def do_update(st: ApexState) -> tuple[ApexState, dict]:
            s_rng, e_rng = jax.random.split(rng)
            batch = replay_lib.sample(rcfg, st.replay, s_rng, cfg.batch_size)
            items = batch.items
            if cfg.compress_obs:  # decode fuses into the learner forward
                items = dict(items)
                items["obs"] = codec.decode(codec.EncodedObs(**items["obs"]))
                items["next_obs"] = codec.decode(
                    codec.EncodedObs(**items["next_obs"]))
            weights = _global_is_weights(cfg, batch, st.replay.size, axis_name)
            params, opt_state, new_prios, metrics = agent.update(
                st.params, st.target_params, st.opt_state, optimizer,
                items, weights, axis_name)
            rep = replay_lib.set_priorities(rcfg, st.replay, batch.indices, new_prios)
            step = st.learner_step + 1
            target = optim.periodic_target_update(
                params, st.target_params, step, cfg.target_update_period)
            # periodic eviction (paper: every 100 learning steps)
            if cfg.eviction == "fifo":
                rep = jax.lax.cond(
                    step % cfg.evict_interval == 0,
                    lambda r: replay_lib.evict_fifo(rcfg, r), lambda r: r, rep)
            else:
                evict_num = cfg.evict_num or cfg.batch_size
                rep = jax.lax.cond(
                    (step % cfg.evict_interval == 0) & (rep.size > rcfg.soft_cap),
                    lambda r: replay_lib.evict_prioritized(rcfg, r, e_rng, evict_num),
                    lambda r: r, rep)
            st = st._replace(params=params, opt_state=opt_state,
                             target_params=target, replay=rep, learner_step=step)
            return st, {**metrics, "updated": jnp.ones((), jnp.float32)}

        def skip(st: ApexState) -> tuple[ApexState, dict]:
            zero = {k: jnp.zeros((), jnp.float32) for k in _metric_keys(agent)}
            return st, {**zero, "updated": jnp.zeros((), jnp.float32)}

        return jax.lax.cond(ready, do_update, skip, st)

    if cfg.learner_steps_per_iter == 0:   # actor-only mode (ablations)
        zero = {k: jnp.zeros((), jnp.float32) for k in _metric_keys(agent)}
        return state, {**zero, "updated": jnp.zeros((), jnp.float32)}
    rng, sub = jax.random.split(state.rng)
    step_rngs = jax.random.split(sub, cfg.learner_steps_per_iter)
    state = state._replace(rng=rng)
    state, metrics = jax.lax.scan(
        lambda st, r: one_step(st, r), state, step_rngs)
    return state, jax.tree.map(lambda m: m[-1], metrics)


def _metric_keys(agent) -> tuple[str, ...]:
    from repro.core.agents import DPGAgent
    if isinstance(agent, DPGAgent):
        return ("critic_loss", "policy_loss", "mean_q")
    return ("loss", "mean_q", "mean_abs_td")


# ---------------------------------------------------------------------------
# Full iteration + distribution wrappers
# ---------------------------------------------------------------------------

def train_iteration(cfg: ApexConfig, env, agent, optimizer, state: ApexState,
                    shard_id: jax.Array | int = 0,
                    axis_name: str | None = None) -> tuple[ApexState, dict]:
    # Periodic actor parameter refresh (paper: every 400 frames).
    sync = (state.iteration % cfg.param_sync_period) == 0
    actor_params = jax.tree.map(
        lambda p, a: jnp.where(sync, p, a), state.params, state.actor_params)
    state = state._replace(actor_params=actor_params)

    state, actor_metrics = actor_phase(cfg, env, agent, state, shard_id)
    state, learner_metrics = learner_phase(cfg, agent, optimizer, state, axis_name)
    state = state._replace(iteration=state.iteration + 1)
    return state, {**actor_metrics, **learner_metrics,
                   "replay_size": state.replay.size.astype(jnp.float32),
                   "frames": state.frames.astype(jnp.float32)}


def make_train_fn(cfg: ApexConfig, env, agent, optimizer, mesh=None,
                  data_axis: str = "data"):
    """Build (init_fn, step_fn).

    Without a mesh: single-shard jitted loop (tests/examples). With a mesh:
    ``shard_map`` over the data axis — replicated learner state, per-shard
    replay/envs; collectives are the gradient pmean + the IS/min-fill scalars.
    """
    if mesh is None:
        init_fn = jax.jit(
            lambda rng: init_state(cfg, env, agent, optimizer, rng, 0))
        step_fn = jax.jit(
            lambda st: train_iteration(cfg, env, agent, optimizer, st, 0, None))
        return init_fn, step_fn

    shard_map = jax.shard_map

    def per_shard_init(rng):
        sid = jax.lax.axis_index(data_axis)
        st = init_state(cfg, env, agent, optimizer, rng, sid)
        return _add_leading(st)

    def per_shard_step(st):
        sid = jax.lax.axis_index(data_axis)
        st = _strip_leading(st)
        st, metrics = train_iteration(cfg, env, agent, optimizer, st, sid, data_axis)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, data_axis), metrics)
        return _add_leading(st), metrics

    def state_specs():
        def spec_for(field, leaf_spec):
            return leaf_spec
        reps = {f: P() for f in REPLICATED_FIELDS}
        return ApexState(**reps, **{
            f: P(data_axis) for f in ApexState._fields if f not in reps})

    specs = state_specs()
    init_fn = jax.jit(shard_map(
        per_shard_init, mesh=mesh, in_specs=P(),
        out_specs=specs, check_vma=False))
    step_fn = jax.jit(shard_map(
        per_shard_step, mesh=mesh, in_specs=(specs,),
        out_specs=(specs, P()), check_vma=False))
    return init_fn, step_fn


def _add_leading(st: ApexState) -> ApexState:
    """Re-attach the per-shard leading axis expected by shard_map out_specs."""
    return ApexState(**{
        f: (getattr(st, f) if f in REPLICATED_FIELDS
            else jax.tree.map(lambda x: x[None], getattr(st, f)))
        for f in ApexState._fields})


def _strip_leading(st: ApexState) -> ApexState:
    return ApexState(**{
        f: (getattr(st, f) if f in REPLICATED_FIELDS
            else jax.tree.map(lambda x: x[0], getattr(st, f)))
        for f in ApexState._fields})
