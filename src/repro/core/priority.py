"""Priority, importance-sampling and exploration-ladder math (Ape-X §3/§4.1).

- priorities are |TD error| (proportional variant, Schaul et al. 2016);
- the replay stores ``(|delta| + eps)^alpha`` in sum-tree leaves (alpha=0.6);
- sampled batches are corrected with importance weights
  ``w_i = (N * P(i))^-beta / max_j w_j`` (beta=0.4);
- actor ``i`` of ``N`` explores with ``eps_i = eps^(1 + i/(N-1) * ladder_alpha)``
  (eps=0.4, ladder_alpha=7), constant through training.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import sampling

# Paper defaults (§4.1, Appendix C/D).
PRIORITY_EXPONENT = 0.6       # alpha_sample
IS_EXPONENT = 0.4             # beta
EVICT_EXPONENT = -0.4         # alpha_evict (Ape-X DPG, Appendix D)
EPSILON_BASE = 0.4            # eps
EPSILON_ALPHA = 7.0           # ladder alpha
MIN_PRIORITY = 1e-4           # numerical floor so no transition starves


def to_leaf(priority: jax.Array, alpha: float = PRIORITY_EXPONENT) -> jax.Array:
    """Map raw priority |delta| to the sum-tree leaf value p^alpha."""
    return jnp.power(jnp.maximum(jnp.abs(priority), MIN_PRIORITY), alpha)


def importance_weights(
    leaf_values: jax.Array,
    total_mass: jax.Array,
    num_items: jax.Array,
    beta: float = IS_EXPONENT,
) -> jax.Array:
    """Max-normalized IS weights for a sampled batch.

    ``leaf_values`` are the p^alpha masses of the sampled leaves; P(i) =
    leaf/total. Normalizing by the batch max keeps weights <= 1 (paper follows
    Schaul et al. 2016). The formula lives in ``repro.core.sampling`` (this is
    its single-shard specialization) so sharded paths provably match it.
    """
    w = sampling.raw_weights(leaf_values, total_mass, num_items, beta)
    return sampling.max_normalize(w)


def epsilon_ladder(
    num_actors: int,
    base: float = EPSILON_BASE,
    alpha: float = EPSILON_ALPHA,
) -> jax.Array:
    """eps_i = base^(1 + i/(N-1)*alpha) for i in [0, N)."""
    if num_actors == 1:
        return jnp.array([base], dtype=jnp.float32)
    i = jnp.arange(num_actors, dtype=jnp.float32)
    return jnp.power(base, 1.0 + i / (num_actors - 1) * alpha)


def fixed_epsilon_set(num_actors: int, values=(0.5, 0.4, 0.3, 0.2, 0.1, 0.01)) -> jax.Array:
    """Appendix B ablation: a small fixed set of eps values tiled across actors."""
    vals = jnp.asarray(values, dtype=jnp.float32)
    return vals[jnp.arange(num_actors) % len(values)]


def td_error_nstep(
    q_sa: jax.Array,
    returns: jax.Array,
    discount_n: jax.Array,
    bootstrap: jax.Array,
) -> jax.Array:
    """n-step TD error  delta = R_{t:t+n} + gamma^n * bootstrap - Q(S_t, A_t)."""
    return returns + discount_n * bootstrap - q_sa
