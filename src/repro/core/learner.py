"""Learning rules: Ape-X DQN (double-Q + multi-step + dueling via the network),
Ape-X DPG (deterministic policy gradients), and the prioritized sequence-model
objective used for the assigned LLM-scale architectures.

Every loss takes max-normalized importance weights from the replay sample and
returns the fresh |TD| (or per-sequence loss) priorities the learner writes
back (Alg. 2 lines 5-8).
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import priority as prio


class LossOut(NamedTuple):
    loss: jax.Array           # scalar
    new_priorities: jax.Array # (B,)
    aux: dict


# ---------------------------------------------------------------------------
# Ape-X DQN (§3.1): double Q-learning, n-step bootstrap, dueling head in net.
# ---------------------------------------------------------------------------

def dqn_loss(
    params: Any,
    target_params: Any,
    apply_fn: Callable[[Any, jax.Array], jax.Array],  # params, obs -> (B, A)
    obs: jax.Array,
    action: jax.Array,
    returns: jax.Array,
    discount_n: jax.Array,
    next_obs: jax.Array,
    is_weights: jax.Array,
) -> LossOut:
    """l(theta) = 1/2 (G_t - q(S_t, A_t, theta))^2 with
    G_t = R_{t:t+n} + gamma^n q(S_{t+n}, argmax_a q(S_{t+n}, a, theta), theta^-).
    """
    q = apply_fn(params, obs)                                    # (B, A)
    q_sa = jnp.take_along_axis(q, action[:, None], axis=-1)[:, 0]
    q_next_online = apply_fn(params, next_obs)
    a_star = jnp.argmax(q_next_online, axis=-1)
    q_next_target = apply_fn(target_params, next_obs)
    bootstrap = jnp.take_along_axis(q_next_target, a_star[:, None], axis=-1)[:, 0]
    g = returns + discount_n * jax.lax.stop_gradient(bootstrap)
    td = g - q_sa
    loss = 0.5 * jnp.mean(is_weights * jnp.square(td))
    return LossOut(loss, jnp.abs(jax.lax.stop_gradient(td)),
                   {"mean_q": q_sa.mean(), "mean_abs_td": jnp.abs(td).mean()})


# ---------------------------------------------------------------------------
# Ape-X DPG (§3.2, Appendix D).
# ---------------------------------------------------------------------------

def dpg_critic_loss(
    critic_params: Any,
    target_critic_params: Any,
    target_policy_params: Any,
    critic_fn: Callable[[Any, jax.Array, jax.Array], jax.Array],  # (B,)
    policy_fn: Callable[[Any, jax.Array], jax.Array],             # (B, adim)
    obs: jax.Array,
    action: jax.Array,
    returns: jax.Array,
    discount_n: jax.Array,
    next_obs: jax.Array,
    is_weights: jax.Array,
) -> LossOut:
    """l(psi) = 1/2 (G_t - q(S_t, A_t, psi))^2 with
    G_t = R_{t:t+n} + gamma^n q(S_{t+n}, pi(S_{t+n}, phi^-), psi^-)."""
    q_sa = critic_fn(critic_params, obs, action)
    a_next = policy_fn(target_policy_params, next_obs)
    bootstrap = critic_fn(target_critic_params, next_obs, a_next)
    g = returns + discount_n * jax.lax.stop_gradient(bootstrap)
    td = g - q_sa
    loss = 0.5 * jnp.mean(is_weights * jnp.square(td))
    return LossOut(loss, jnp.abs(jax.lax.stop_gradient(td)),
                   {"mean_q": q_sa.mean()})


def dpg_policy_loss(
    policy_params: Any,
    critic_params: Any,
    critic_fn: Callable[[Any, jax.Array, jax.Array], jax.Array],
    policy_fn: Callable[[Any, jax.Array], jax.Array],
    obs: jax.Array,
    is_weights: jax.Array,
    action_grad_clip: float = 1.0,
) -> jax.Array:
    """Gradient ascent on q(S_t, pi(S_t, phi), psi); the gradient through the
    action is clipped element-wise to [-c, c] (Appendix D)."""
    def q_of_action(a):
        return jnp.sum(is_weights * critic_fn(critic_params, obs, a))

    a = policy_fn(policy_params, obs)
    dq_da = jax.grad(q_of_action)(a)
    dq_da = jnp.clip(dq_da, -action_grad_clip, action_grad_clip)
    # ascent on q == descent on -<clip(dq/da), a>
    return -jnp.sum(jax.lax.stop_gradient(dq_da) * a) / jnp.maximum(obs.shape[0], 1)


# ---------------------------------------------------------------------------
# Prioritized sequence replay objective (paper §6: "prioritize sequences of
# past experiences") — the LLM-scale integration for the assigned archs.
# ---------------------------------------------------------------------------

def sequence_loss(
    params: Any,
    apply_fn: Callable[..., jax.Array],   # params, tokens -> (B, S, V) logits
    tokens: jax.Array,                    # (B, S) int32
    labels: jax.Array,                    # (B, S) int32, -1 = masked
    is_weights: jax.Array,                # (B,)
    **apply_kwargs,
) -> LossOut:
    """IS-weighted next-token cross entropy; per-sequence mean loss is the
    fresh priority (the sequence-level analogue of |TD|)."""
    logits = apply_fn(params, tokens, **apply_kwargs)
    mask = (labels >= 0).astype(jnp.float32)
    safe_labels = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, safe_labels[..., None], axis=-1)[..., 0]
    per_seq = (nll * mask).sum(-1) / jnp.maximum(mask.sum(-1), 1.0)   # (B,)
    loss = jnp.mean(is_weights * per_seq)
    return LossOut(loss, jax.lax.stop_gradient(per_seq),
                   {"ppl_proxy": per_seq.mean()})
