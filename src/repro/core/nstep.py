"""n-step transition construction (Ape-X Appendix F, "Adding Data").

Two equivalent implementations:

* :class:`Ring` — the paper's streaming circular buffer of the last ``n+1``
  step records per actor lane; each env step emits (at most) one valid
  transition. This is the faithful per-step construction.
* :func:`from_trajectory` — bulk construction over a finished rollout chunk,
  the TPU-friendly layout used by the SPMD actor phase (one fused pass over
  ``(lanes, T)`` rewards/discounts). ``repro.kernels.nstep_return`` provides
  the Pallas version; this is its oracle.

Both truncate multi-step returns at episode boundaries via the discount
product (a terminal step carries ``discount == 0``, zeroing every later
reward in the window and the bootstrap term).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class Ring(NamedTuple):
    """Circular buffer of the last ``n+1`` per-lane step records.

    ``record`` is a pytree of arrays shaped ``(lanes, n+1, ...)`` — typically
    {obs, action, qvals} so initial priorities reuse the actor's buffered
    Q-values instead of recomputing them (Appendix F).
    """

    record: Any            # pytree, arrays (lanes, n+1, ...)
    reward: jax.Array      # (lanes, n+1)  R_{t+1} stored with step t
    discount: jax.Array    # (lanes, n+1)  gamma_{t+1}, 0 at terminal
    ptr: jax.Array         # scalar int32, next write slot
    count: jax.Array       # scalar int32, total pushes


class Transition(NamedTuple):
    """One n-step transition: (S_t, A_t, R_{t:t+n}, gamma^n, S_{t+n})."""

    first: Any             # record at time t        (pytree, (lanes, ...))
    last: Any              # record at time t+n      (pytree, (lanes, ...))
    returns: jax.Array     # (lanes,) n-step discounted return
    discount_n: jax.Array  # (lanes,) product of n discounts
    valid: jax.Array       # (lanes,) bool — ring warm (broadcast scalar)


def ring_init(record_example: Any, n: int, lanes: int) -> Ring:
    """Empty ring for n-step construction; ``record_example`` gives per-lane shapes."""
    rec = jax.tree.map(
        lambda a: jnp.zeros((lanes, n + 1) + jnp.shape(a)[1:], jnp.asarray(a).dtype),
        record_example,
    )
    return Ring(
        record=rec,
        reward=jnp.zeros((lanes, n + 1), jnp.float32),
        discount=jnp.zeros((lanes, n + 1), jnp.float32),
        ptr=jnp.zeros((), jnp.int32),
        count=jnp.zeros((), jnp.int32),
    )


def ring_push(ring: Ring, record: Any, reward: jax.Array, discount: jax.Array, n: int) -> tuple[Ring, Transition]:
    """Push step ``t``'s record; emit the transition for step ``t-n`` if warm.

    After the push the ring holds steps ``t-n .. t``; the oldest slot (the next
    write position) is step ``t-n`` and the slot just written is step ``t``.
    """
    cap = n + 1
    slot = ring.ptr % cap
    new_rec = jax.tree.map(lambda buf, x: buf.at[:, slot].set(x), ring.record, record)
    new_reward = ring.reward.at[:, slot].set(reward)
    new_discount = ring.discount.at[:, slot].set(discount)
    new_ring = Ring(new_rec, new_reward, new_discount, (ring.ptr + 1) % cap, ring.count + 1)

    oldest = new_ring.ptr % cap  # slot of step t-n
    returns = jnp.zeros(reward.shape, jnp.float32)
    disc = jnp.ones(reward.shape, jnp.float32)
    for k in range(n):
        s = (oldest + k) % cap
        returns = returns + disc * new_reward[:, s]
        disc = disc * new_discount[:, s]
    first = jax.tree.map(lambda buf: buf[:, oldest], new_rec)
    last = jax.tree.map(lambda buf: buf[:, slot], new_rec)
    warm = new_ring.count >= cap
    valid = jnp.broadcast_to(warm, reward.shape)
    return new_ring, Transition(first, last, returns, disc, valid)


def from_trajectory(reward: jax.Array, discount: jax.Array, n: int) -> tuple[jax.Array, jax.Array]:
    """Bulk n-step returns over a rollout.

    Args:
      reward:   (lanes, T) with reward[t] = R_{t+1}.
      discount: (lanes, T) with discount[t] = gamma_{t+1} (0 at terminal).
      n:        bootstrap horizon.

    Returns:
      returns:    (lanes, T-n+1) with returns[t]  = sum_{k<n} R_{t+k+1} prod_{j<k} gamma
      discount_n: (lanes, T-n+1) with discount_n[t] = prod_{k<n} gamma_{t+k+1}
    """
    lanes, T = reward.shape
    if T < n:
        raise ValueError(f"trajectory length {T} < n-step horizon {n}")
    W = T - n + 1
    returns = jnp.zeros((lanes, W), jnp.float32)
    disc = jnp.ones((lanes, W), jnp.float32)
    for k in range(n):
        returns = returns + disc * reward[:, k:k + W]
        disc = disc * discount[:, k:k + W]
    return returns, disc
