"""Acting: exploration policies and actor-side initial priorities (Alg. 1).

The Ape-X actor's defining move is computing *suitable initial priorities
online* from the Q-values it already evaluated while acting (paper §3,
Appendix F) — not max-priority like Schaul et al. 2016, which at Ape-X ingest
rates would collapse sampling onto the newest data. Everything here is pure
and vectorized over actor lanes; the stale parameter copy the actor acts with
is managed by ``repro.core.apex`` (``param_sync_period``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import priority as prio


def egreedy_action(rng: jax.Array, qvals: jax.Array, epsilon: jax.Array) -> jax.Array:
    """Per-lane epsilon-greedy over (lanes, num_actions) Q-values.

    ``epsilon`` is (lanes,) — each lane is one "actor" of the paper's ladder.
    """
    lanes, num_actions = qvals.shape
    explore_rng, action_rng = jax.random.split(rng)
    greedy = jnp.argmax(qvals, axis=-1)
    random = jax.random.randint(action_rng, (lanes,), 0, num_actions)
    explore = jax.random.uniform(explore_rng, (lanes,)) < epsilon
    return jnp.where(explore, random, greedy).astype(jnp.int32)


def gaussian_action(rng: jax.Array, mean_action: jax.Array, sigma: float = 0.3,
                    low: float = -1.0, high: float = 1.0) -> jax.Array:
    """DPG exploration (Appendix D): N(0, sigma) noise per action dim, clipped.

    The paper deliberately replaces the original DDPG's Ornstein-Uhlenbeck
    process with uncorrelated Gaussian noise.
    """
    noise = sigma * jax.random.normal(rng, mean_action.shape, mean_action.dtype)
    return jnp.clip(mean_action + noise, low, high)


def initial_priorities_dqn(
    q_first: jax.Array,       # (B, A) buffered q(S_t, *) from acting time
    action: jax.Array,        # (B,)   A_t
    returns: jax.Array,       # (B,)   R_{t:t+n}
    discount_n: jax.Array,    # (B,)   gamma^n (0 past terminal)
    q_last: jax.Array,        # (B, A) buffered q(S_{t+n}, *)
) -> jax.Array:
    """|n-step TD| from the actor's buffered Q-values (Appendix F).

    Bootstrap is greedy w.r.t. the actor's own (stale) copy — the actor holds
    a single parameter set, so no online/target split here; the learner
    refreshes the priority with the full double-Q error after sampling.
    """
    q_sa = jnp.take_along_axis(q_first, action[:, None], axis=-1)[:, 0]
    bootstrap = q_last.max(axis=-1)
    return jnp.abs(prio.td_error_nstep(q_sa, returns, discount_n, bootstrap))


def initial_priorities_dpg(
    q_sa_first: jax.Array,    # (B,) buffered critic value q(S_t, A_t)
    returns: jax.Array,
    discount_n: jax.Array,
    q_boot_last: jax.Array,   # (B,) buffered q(S_{t+n}, pi(S_{t+n}))
) -> jax.Array:
    """|n-step TD| as given by the (stale) critic (Appendix D)."""
    return jnp.abs(prio.td_error_nstep(q_sa_first, returns, discount_n, q_boot_last))
