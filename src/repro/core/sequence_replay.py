"""Prioritized *sequence* replay — the paper's technique as a first-class
data-selection layer for large sequence models (paper §6: "the Ape-X framework
may be adapted to prioritize sequences of past experiences").

Roles map 1:1 onto Algorithm 1/2:

* **Ingest ("acting", Alg. 1)** — each ``data``-axis shard scores incoming
  sequences with a *stale* parameter copy (``actor_params``, refreshed every
  ``param_sync_period`` rounds) to produce initial priorities = per-sequence
  loss. This is the actor-side online priority computation, the paper's key
  scalability fix: new data enters the memory with informative priorities
  instead of max-priority.
* **Learn (Alg. 2)** — sample a prioritized batch, apply the IS-weighted
  next-token loss, write back fresh per-sequence priorities, periodically
  evict FIFO excess.

The replay machinery is exactly ``repro.core.replay`` — the sum-tree neither
knows nor cares that items are 4k-token sequences instead of Atari
transitions.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import learner as learner_lib
from repro.core import replay as replay_lib
from repro.optim import optimizers as optim


@dataclasses.dataclass(frozen=True)
class SeqReplayConfig:
    replay: replay_lib.ReplayConfig
    seq_len: int
    batch_size: int            # learner batch (sequences) per shard
    ingest_batch: int          # sequences scored + added per round per shard
    param_sync_period: int = 8
    learner_steps_per_round: int = 1
    evict_interval: int = 100


class SeqReplayState(NamedTuple):
    params: Any
    opt_state: Any
    actor_params: Any          # stale scoring copy
    replay: replay_lib.ReplayState
    rng: jax.Array
    round: jax.Array
    learner_step: jax.Array


def init_state(cfg: SeqReplayConfig, params: Any, optimizer: optim.Optimizer,
               rng: jax.Array) -> SeqReplayState:
    item = {
        "tokens": jnp.zeros((cfg.seq_len,), jnp.int32),
        "labels": jnp.zeros((cfg.seq_len,), jnp.int32),
    }
    return SeqReplayState(
        params=params,
        opt_state=optimizer.init(params),
        actor_params=jax.tree.map(jnp.copy, params),
        replay=replay_lib.init(cfg.replay, item),
        rng=rng,
        round=jnp.zeros((), jnp.int32),
        learner_step=jnp.zeros((), jnp.int32),
    )


def score_sequences(apply_fn: Callable[..., jax.Array], params: Any,
                    tokens: jax.Array, labels: jax.Array, **kw) -> jax.Array:
    """Actor-side initial priorities: per-sequence mean NLL under the stale
    copy (the sequence analogue of the buffered-Q |TD| in Appendix F)."""
    out = learner_lib.sequence_loss(
        params, apply_fn, tokens, labels,
        jnp.ones((tokens.shape[0],), jnp.float32), **kw)
    return out.new_priorities


def ingest(cfg: SeqReplayConfig, apply_fn, state: SeqReplayState,
           tokens: jax.Array, labels: jax.Array) -> SeqReplayState:
    """Score a fresh batch with the stale copy and bulk-add (Alg. 1 l.9-11)."""
    prios = score_sequences(apply_fn, state.actor_params, tokens, labels)
    rep = replay_lib.add_fifo(cfg.replay, state.replay,
                              {"tokens": tokens, "labels": labels}, prios)
    return state._replace(replay=rep)


def learner_step(cfg: SeqReplayConfig, apply_fn, optimizer: optim.Optimizer,
                 state: SeqReplayState,
                 axis_name: str | None = None) -> tuple[SeqReplayState, dict]:
    """One prioritized update (Alg. 2): sample -> IS-weighted loss -> fresh
    priorities -> periodic FIFO eviction."""
    rng, s_rng = jax.random.split(state.rng)
    batch = replay_lib.sample(cfg.replay, state.replay, s_rng, cfg.batch_size)

    def loss_fn(p):
        out = learner_lib.sequence_loss(
            p, apply_fn, batch.items["tokens"], batch.items["labels"],
            batch.is_weights)
        return out.loss, out

    (loss, out), grads = jax.value_and_grad(loss_fn, has_aux=True)(state.params)
    if axis_name is not None:
        grads = jax.lax.pmean(grads, axis_name)
    grads = optim.clip_by_global_norm(grads, 1.0)
    updates, opt_state = optimizer.update(grads, state.opt_state, state.params)
    params = optim.apply_updates(state.params, updates)
    rep = replay_lib.set_priorities(cfg.replay, state.replay, batch.indices,
                                    out.new_priorities)
    step = state.learner_step + 1
    rep = jax.lax.cond(step % cfg.evict_interval == 0,
                       lambda r: replay_lib.evict_fifo(cfg.replay, r),
                       lambda r: r, rep)
    state = state._replace(params=params, opt_state=opt_state, replay=rep,
                           rng=rng, learner_step=step)
    return state, {"loss": loss, "mean_priority": out.new_priorities.mean(),
                   "max_is_weight": batch.is_weights.max()}


def round_step(cfg: SeqReplayConfig, apply_fn, optimizer: optim.Optimizer,
               state: SeqReplayState, tokens: jax.Array, labels: jax.Array,
               axis_name: str | None = None) -> tuple[SeqReplayState, dict]:
    """One full round: param sync -> ingest (acting) -> learner steps."""
    sync = (state.round % cfg.param_sync_period) == 0
    actor_params = jax.tree.map(
        lambda p, a: jnp.where(sync, p, a), state.params, state.actor_params)
    state = state._replace(actor_params=actor_params)
    state = ingest(cfg, apply_fn, state, tokens, labels)
    metrics = {}
    for _ in range(cfg.learner_steps_per_round):
        state, metrics = learner_step(cfg, apply_fn, optimizer, state, axis_name)
    return state._replace(round=state.round + 1), metrics
