"""Backend-agnostic global importance-sampling weight math.

The paper's learner corrects prioritized sampling with importance weights
``w_i = (N * P(i))^-beta / max_j w_j`` computed against the *global* sampling
distribution, even when the replay memory is physically sharded. With equal
per-shard sample quotas the actual distribution is

    P(i) = leaf_i / (shard_total(i) * num_shards)

so the correction needs exactly two global reductions: the global item count
``N`` and the global max weight. This module holds that formula **once** and
exposes it through two reduction backends:

* ``collective_is_weights`` — inside ``shard_map``/``vmap`` with a named
  axis: the reductions are ``lax.psum`` / ``lax.pmax`` collectives (the
  synchronous ``repro.core.apex`` driver).
* ``merged_is_weights``     — over host-stacked per-shard sub-samples: the
  reductions are plain ``sum`` / ``max`` over the stacked axis (the async
  ``repro.runtime.fabric.ReplayFabric`` learner-side merge).

Both call the same ``raw_weights`` kernel, so the sync and async paths cannot
drift numerically; ``repro.core.priority.importance_weights`` (the
single-shard case) delegates here too.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class LearnerBatch(NamedTuple):
    """The learner-plane sample contract: everything a learner consumes.

    This is the *whole* surface the learner sees of the replay system —
    shard-internal fields (leaf masses, per-shard totals) stay behind the
    replay/fabric boundary, which is what lets the same learner loop run
    against an in-process fabric, a staged device pipeline, or a remote
    fabric over the wire (``repro.runtime.sources``). ``indices`` are global
    ``(shard, slot)`` keys, so a priority write-back of any subset/order of
    them routes to the owning shards unchanged regardless of transport.
    """

    indices: jax.Array     # (B,) global (shard, slot) keys
    items: Any             # pytree of (B, ...) arrays
    is_weights: jax.Array  # (B,) globally max-normalized IS weights


def raw_weights(leaf_mass: jax.Array, scaled_total: jax.Array,
                num_items: jax.Array, beta: float) -> jax.Array:
    """Unnormalized ``(N * P(i))^-beta`` for leaves with mass ``leaf_mass``.

    ``scaled_total`` is the denominator of P(i): the owning shard's total
    priority mass times the number of shards (``num_shards == 1`` recovers
    the plain single-buffer probability). ``num_items`` is the *global* live
    item count N.
    """
    p = leaf_mass / jnp.maximum(scaled_total, 1e-30)
    n = jnp.maximum(num_items.astype(jnp.float32), 1.0)
    return jnp.power(n * jnp.maximum(p, 1e-30), -beta)


def max_normalize(w: jax.Array, w_max: jax.Array | None = None) -> jax.Array:
    """Divide by the (global) max weight so corrections only scale down."""
    if w_max is None:
        w_max = jnp.max(w)
    return w / jnp.maximum(w_max, 1e-30)


def collective_is_weights(leaf_mass: jax.Array, total_mass: jax.Array,
                          size: jax.Array, num_shards: int, beta: float,
                          axis_name: str) -> jax.Array:
    """IS weights inside a ``shard_map``/``vmap`` body: N and the max weight
    are reduced with one ``psum`` and one ``pmax`` over ``axis_name``."""
    n_global = jax.lax.psum(size, axis_name)
    w = raw_weights(leaf_mass, total_mass * num_shards, n_global, beta)
    return max_normalize(w, jax.lax.pmax(jnp.max(w), axis_name))


def merged_is_weights(leaf_mass: jax.Array, total_mass: jax.Array,
                      sizes: jax.Array, beta: float) -> jax.Array:
    """IS weights for host-merged per-shard sub-samples.

    ``leaf_mass`` is ``(S, b)`` — one row of sampled leaf masses per shard —
    ``total_mass`` and ``sizes`` are ``(S,)`` per-shard totals/live counts.
    The reductions that were collectives in ``collective_is_weights`` are
    plain ``sum``/``max`` over the stacked shard axis; the per-item formula
    is the identical ``raw_weights``. Returns ``(S, b)`` weights.
    """
    num_shards = leaf_mass.shape[0]
    n_global = jnp.sum(sizes)
    w = raw_weights(leaf_mass, (total_mass * num_shards)[:, None],
                    n_global, beta)
    return max_normalize(w)
