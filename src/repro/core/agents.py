"""Agent plug-ins: Ape-X DQN and Ape-X DPG behind one protocol.

The paper stresses the framework "may be combined with any off-policy
reinforcement learning update" (§6); ``repro.core.apex`` is generic over this
protocol:

  init(rng, obs_example) -> params
  act(params, rng, obs, eps) -> (action, act_aux)      # aux buffers the
      Q-values evaluated while acting, so initial priorities come for free
      (Appendix F "Adding Data")
  initial_priorities(first_aux, action, returns, discount_n, last_aux)
  update(params, target_params, opt_state, optimizer, items, is_weights,
         axis_name) -> (params, opt_state, new_priorities, metrics)

``axis_name`` is the ``data`` mesh axis: gradients are psum-averaged across
shards (the learner is data-parallel), everything else is shard-local.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import actor as actor_lib
from repro.core import learner as learner_lib
from repro.models.qnetworks import DPGActor, DPGCritic, DuelingDQN
from repro.optim import optimizers as optim


def _pmean(tree: Any, axis_name: str | None) -> Any:
    if axis_name is None:
        return tree
    return jax.lax.pmean(tree, axis_name)


@dataclasses.dataclass(frozen=True)
class DQNAgent:
    """Double-Q + n-step + dueling (paper §3.1, Appendix C)."""

    net: DuelingDQN
    grad_clip: float = 40.0

    def init(self, rng: jax.Array, obs_example: jax.Array) -> Any:
        return self.net.init(rng, obs_example)

    def act(self, params: Any, rng: jax.Array, obs: jax.Array,
            eps: jax.Array) -> tuple[jax.Array, dict]:
        q = self.net.apply(params, obs)                       # (lanes, A)
        a = actor_lib.egreedy_action(rng, q, eps)
        return a, {"q": q}

    def initial_priorities(self, first_aux, action, returns, discount_n, last_aux):
        return actor_lib.initial_priorities_dqn(
            first_aux["q"], action, returns, discount_n, last_aux["q"])

    def update(self, params, target_params, opt_state, optimizer, items,
               is_weights, axis_name=None):
        def loss_fn(p):
            out = learner_lib.dqn_loss(
                p, target_params, self.net.apply,
                items["obs"], items["action"], items["returns"],
                items["discount_n"], items["next_obs"], is_weights)
            return out.loss, out

        (loss, out), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        grads = _pmean(grads, axis_name)
        grads = optim.clip_by_global_norm(grads, self.grad_clip)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        metrics = {"loss": loss, **out.aux}
        return params, opt_state, out.new_priorities, metrics


@dataclasses.dataclass(frozen=True)
class DPGAgent:
    """Deterministic policy gradients with a TD critic (paper §3.2, Appendix D)."""

    actor_net: DPGActor
    critic_net: DPGCritic
    sigma: float = 0.3
    action_grad_clip: float = 1.0

    def init(self, rng: jax.Array, obs_example: jax.Array) -> Any:
        a_rng, c_rng = jax.random.split(rng)
        act_example = jnp.zeros((1, self.actor_net.action_dim), jnp.float32)
        return {
            "actor": self.actor_net.init(a_rng, obs_example),
            "critic": self.critic_net.init(c_rng, obs_example, act_example),
        }

    def act(self, params: Any, rng: jax.Array, obs: jax.Array,
            eps: jax.Array) -> tuple[jax.Array, dict]:
        # eps scales exploration noise per lane — the continuous analogue of
        # the eps-ladder (the paper's DPG runs use a single sigma; the ladder
        # reduces to it when all lanes share one value).
        pi = self.actor_net.apply(params["actor"], obs)
        a = actor_lib.gaussian_action(rng, pi, self.sigma)
        a = jnp.where(eps[:, None] > 0, a, pi)  # eps==0 lanes act greedily
        q_sa = self.critic_net.apply(params["critic"], obs, a)
        q_pi = self.critic_net.apply(params["critic"], obs, pi)
        return a, {"q_sa": q_sa, "q_pi": q_pi}

    def initial_priorities(self, first_aux, action, returns, discount_n, last_aux):
        del action
        return actor_lib.initial_priorities_dpg(
            first_aux["q_sa"], returns, discount_n, last_aux["q_pi"])

    def update(self, params, target_params, opt_state, optimizer, items,
               is_weights, axis_name=None):
        def critic_loss_fn(cp):
            out = learner_lib.dpg_critic_loss(
                cp, target_params["critic"], target_params["actor"],
                self.critic_net.apply, self.actor_net.apply,
                items["obs"], items["action"], items["returns"],
                items["discount_n"], items["next_obs"], is_weights)
            return out.loss, out

        def policy_loss_fn(ap):
            return learner_lib.dpg_policy_loss(
                ap, params["critic"], self.critic_net.apply,
                self.actor_net.apply, items["obs"], is_weights,
                self.action_grad_clip)

        (c_loss, out), c_grads = jax.value_and_grad(critic_loss_fn, has_aux=True)(
            params["critic"])
        p_loss, a_grads = jax.value_and_grad(policy_loss_fn)(params["actor"])
        grads = _pmean({"actor": a_grads, "critic": c_grads}, axis_name)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        metrics = {"critic_loss": c_loss, "policy_loss": p_loss, **out.aux}
        return params, opt_state, out.new_priorities, metrics
