"""Observation codec — the paper's PNG-compression analogue (§4.1: "to reduce
memory and bandwidth requirements, observation data is compressed ... when
stored in the replay").

On TPU there is no PNG, but the same 4x saving comes from storing float
observations as uint8 with a per-observation affine (scale, offset) — exact
for data that is already uint8 (Atari frames / ChainWorld), quantized to
1/255 of the dynamic range otherwise. The replay stores the encoded struct;
actors/learners decode on the fly (the paper decompresses on the learner's
CPU in parallel with the GPU — here decode fuses into the forward pass).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class EncodedObs(NamedTuple):
    data: jax.Array      # uint8, original shape
    scale: jax.Array     # (..., 1) f32 per-observation range / 255
    offset: jax.Array    # (..., 1) f32 per-observation min


def encode(obs, feature_dims: int = 1) -> EncodedObs:
    """Quantize trailing ``feature_dims`` axes to uint8 per observation.

    One entry point for both halves of the system: a numpy input (the wire
    codec quantizing on an actor host) runs the host-side numpy math; any
    jax value — including tracers inside jit — runs the device version.
    Both produce the same bytes (property-tested in ``tests/test_net_wire``),
    so callers never pick a backend.

    uint8 inputs pass through losslessly (scale=1, offset=0).
    """
    if isinstance(obs, np.ndarray):
        return _encode_host(obs, feature_dims)
    if obs.dtype == jnp.uint8:
        lead = obs.shape[:obs.ndim - feature_dims] + (1,) * feature_dims
        return EncodedObs(obs, jnp.ones(lead, jnp.float32),
                          jnp.zeros(lead, jnp.float32))
    axes = tuple(range(obs.ndim - feature_dims, obs.ndim))
    x = obs.astype(jnp.float32)
    lo = x.min(axis=axes, keepdims=True)
    hi = x.max(axis=axes, keepdims=True)
    scale = jnp.maximum(hi - lo, 1e-12) / 255.0
    q = jnp.clip(jnp.round((x - lo) / scale), 0, 255).astype(jnp.uint8)
    return EncodedObs(q, scale, lo)


def decode(enc: EncodedObs, dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`encode` (exact for uint8 passthrough). Dispatches
    like :func:`encode`: numpy-leaf structs stay in numpy, jax values
    (including tracers) run the device ops."""
    if isinstance(enc.data, np.ndarray):
        return _decode_host(enc, dtype)
    return (enc.data.astype(jnp.float32) * enc.scale + enc.offset).astype(dtype)


def _encode_host(obs: np.ndarray, feature_dims: int = 1) -> EncodedObs:
    """Host-side (numpy) twin of the device path, same affine/rounding math.

    The wire codec (``repro.net.wire``) quantizes observations on the actor
    host before serialization; running the device version there would cost a
    dispatch + transfer per frame, so this stays in numpy. float32 min/max,
    divide, and round-half-to-even match XLA's CPU lowering elementwise, so
    both paths produce the same bytes (property-tested in
    ``tests/test_net_wire.py``).
    """
    obs = np.asarray(obs)
    if obs.dtype == np.uint8:
        lead = obs.shape[:obs.ndim - feature_dims] + (1,) * feature_dims
        return EncodedObs(obs, np.ones(lead, np.float32),
                          np.zeros(lead, np.float32))
    axes = tuple(range(obs.ndim - feature_dims, obs.ndim))
    x = obs.astype(np.float32)
    lo = x.min(axis=axes, keepdims=True)
    hi = x.max(axis=axes, keepdims=True)
    scale = np.maximum(hi - lo, np.float32(1e-12)) / np.float32(255.0)
    q = np.clip(np.round((x - lo) / scale), 0, 255).astype(np.uint8)
    return EncodedObs(q, scale.astype(np.float32), lo.astype(np.float32))


def _decode_host(enc: EncodedObs, dtype=np.float32) -> np.ndarray:
    return (np.asarray(enc.data, np.float32) * np.asarray(enc.scale)
            + np.asarray(enc.offset)).astype(dtype)


# Former explicit-backend entry points, kept as aliases: ``encode``/``decode``
# now dispatch on the input type, so callers no longer choose a backend.
encode_np = _encode_host
decode_np = _decode_host


def storage_bytes(enc: EncodedObs) -> int:
    """Bytes per stored observation (for the bandwidth accounting)."""
    per = enc.data.size + 4 * (enc.scale.size + enc.offset.size)
    return int(per)
