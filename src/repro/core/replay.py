"""Sharded prioritized replay memory (the Ape-X replay server, TPU-native).

Logically one centralized memory (paper §3); physically each ``data``-axis
shard owns ``capacity/num_shards`` slots plus its own sum-tree, and the only
cross-shard traffic is one scalar (the shard's total priority mass) per
sampling round — the paper's batched-communication principle taken to its
limit. Everything here is per-shard and purely functional; ``repro.core.apex``
maps it over the mesh with ``shard_map``.

Eviction strategies (both from the paper):
  * ``evict_fifo`` — Atari (§4.1): adds are always permitted (soft limit);
    periodically the excess above the soft capacity is removed en masse in
    FIFO order.
  * ``evict_prioritized`` — DPG (Appendix D): victims are sampled with
    probability proportional to ``p^alpha_evict`` (alpha_evict = -0.4), i.e.
    low-priority items are evicted first, keeping rare high-priority
    experience alive longer (the paper's Fig. 5 hypothesis).

Slots are the paper's "keys": a transition's global key is (shard, slot).

Both add modes funnel into one ingest contract — packed items, slot indices,
an ``applied`` lane mask — dispatched like the sum-tree hot ops: a fused
Pallas kernel (``repro.kernels.replay_ingest``) does priority init, storage
scatter, and tree repair in one VMEM round-trip on TPU, with the unfused
XLA chain (:func:`ingest_unfused`) as the bit-identical fallback/oracle.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import priority as prio
from repro.core import sumtree


class ReplayState(NamedTuple):
    storage: Any           # pytree of (C_phys, ...) arrays
    tree: jax.Array        # (2*C_phys,) sum-tree over p^alpha leaves
    write_pos: jax.Array   # scalar int32 (FIFO circular pointer)
    size: jax.Array        # scalar int32, live items
    total_added: jax.Array # scalar int32, lifetime adds (for diagnostics)


class SampleBatch(NamedTuple):
    indices: jax.Array     # (B,) slot ids within this shard
    items: Any             # pytree of (B, ...) arrays
    is_weights: jax.Array  # (B,) max-normalized importance weights
    leaf_mass: jax.Array   # (B,) p^alpha of each sampled slot
    total_mass: jax.Array  # scalar, shard total priority mass
    size: jax.Array        # scalar, shard live-item count at sample time
                           # (feeds the global-N term when shards are merged)


@dataclasses.dataclass(frozen=True)
class ReplayConfig:
    """Static replay configuration (hashable; safe to close over in jit)."""

    capacity: int                      # physical slots per shard (power of 2)
    soft_capacity: int | None = None   # logical limit (FIFO mode); default 7/8 phys
    alpha: float = prio.PRIORITY_EXPONENT
    beta: float = prio.IS_EXPONENT
    evict_alpha: float = prio.EVICT_EXPONENT
    min_fill: int = 128                # learner waits for this many items (paper: 50000 global)

    def __post_init__(self):
        if self.capacity & (self.capacity - 1):
            raise ValueError("replay capacity must be a power of two")

    @property
    def soft_cap(self) -> int:
        return self.soft_capacity if self.soft_capacity is not None else (self.capacity // 8) * 7


def init(cfg: ReplayConfig, item_example: Any) -> ReplayState:
    """Empty replay; ``item_example`` is a pytree giving per-item shapes/dtypes."""
    storage = jax.tree.map(
        lambda a: jnp.zeros((cfg.capacity,) + jnp.shape(a), jnp.asarray(a).dtype),
        item_example,
    )
    return ReplayState(
        storage=storage,
        tree=sumtree.init(cfg.capacity),
        write_pos=jnp.zeros((), jnp.int32),
        size=jnp.zeros((), jnp.int32),
        total_added=jnp.zeros((), jnp.int32),
    )


def _store(storage: Any, idx: jax.Array, items: Any) -> Any:
    return jax.tree.map(lambda buf, x: buf.at[idx].set(x.astype(buf.dtype)), storage, items)


def ingest_unfused(
    cfg: ReplayConfig, state: ReplayState, items: Any, priorities: jax.Array,
    idx: jax.Array, applied: jax.Array,
) -> tuple[Any, jax.Array]:
    """The pre-fusion ingest chain (XLA fallback and the fused op's oracle).

    Three logical dispatches — leaf init, per-buffer storage scatter,
    incremental tree write — with gather-then-scatter semantics throughout:
    masked (``~applied``) lanes re-write their slot's *original* leaf and
    row, so they are no-ops except under duplicate slots, where the scatter's
    last-writer-wins applies. Out-of-range lanes (``add_alloc``'s overflow
    fill value ``capacity``) drop on every scatter.
    """
    leaf = jnp.where(applied, prio.to_leaf(priorities, cfg.alpha),
                     sumtree.leaves(state.tree)[idx])
    storage = jax.tree.map(
        lambda buf, x: buf.at[idx].set(
            jnp.where(jnp.expand_dims(applied, tuple(range(1, x.ndim))),
                      x.astype(buf.dtype), buf[idx])),
        state.storage, items)
    tree = sumtree.write(state.tree, idx, leaf)
    return storage, tree


def _ingest(
    cfg: ReplayConfig, state: ReplayState, items: Any, priorities: jax.Array,
    idx: jax.Array, applied: jax.Array,
) -> tuple[Any, jax.Array]:
    """One fused ingest: priority init + storage scatter + tree repair.

    Both add modes reduce to this contract once their slot indices and lane
    mask are computed (FIFO cursor arithmetic / ``free_slot_idx``). Dispatch
    follows the sum-tree hot ops (``set_backend`` / ``REPRO_SUMTREE_BACKEND``):
    the Pallas kernel does the whole thing in one VMEM round-trip on TPU
    (``interpret`` runs it under the interpreter for CPU CI); the ``xla``
    backend keeps :func:`ingest_unfused`, which an enclosing jit fuses into
    one XLA program. All paths are bit-identical.
    """
    bk = sumtree.hot_backend(cfg.capacity)
    if bk in ("pallas", "interpret"):
        from repro.kernels.replay_ingest.ops import replay_ingest
        tree, storage = replay_ingest(
            state.tree, state.storage, idx, priorities, applied, items,
            alpha=cfg.alpha, interpret=(bk == "interpret"))
        return storage, tree
    return ingest_unfused(cfg, state, items, priorities, idx, applied)


def add_fifo(
    cfg: ReplayConfig, state: ReplayState, items: Any, priorities: jax.Array,
    valid: jax.Array | None = None,
) -> ReplayState:
    """Batched circular add with actor-computed initial priorities (Alg. 1 l.10-11).

    Adding is always permitted (soft limit): if the physical buffer is full the
    oldest slots are overwritten, which coincides with FIFO eviction. ``valid``
    masks out warm-up/invalid lanes (their slots are not consumed).
    """
    (batch,) = priorities.shape
    if valid is None:
        valid = jnp.ones((batch,), bool)
    # Pack valid lanes first so invalid ones don't consume slots: stable argsort
    # of ~valid puts valid lane ids in front, preserving order.
    order = jnp.argsort(~valid, stable=True)
    items = jax.tree.map(lambda x: x[order], items)
    priorities = priorities[order]
    n_valid = valid.sum().astype(jnp.int32)

    offs = jnp.arange(batch, dtype=jnp.int32)
    idx = (state.write_pos + offs) % cfg.capacity
    # Invalid tail lanes land on the same circular indices but masked: they
    # re-write their slot's old leaf/row (a no-op), and since write_pos only
    # advances by n_valid the next add claims those slots anyway.
    applied = offs < n_valid
    storage, tree = _ingest(cfg, state, items, priorities, idx, applied)
    return ReplayState(
        storage=storage,
        tree=tree,
        write_pos=(state.write_pos + n_valid) % cfg.capacity,
        size=jnp.minimum(state.size + n_valid, cfg.capacity),
        total_added=state.total_added + n_valid,
    )


def free_slot_idx(live: jax.Array, batch: int) -> jax.Array:
    """First ``batch`` free slots via masked-cumsum compaction: rank each
    free slot among the free slots (in index order, like the argsort this
    replaced, but O(C) instead of O(C log C)) and scatter them into the
    result. Lanes beyond the free-slot count keep an *out-of-range* fill
    value, so their downstream leaf/storage scatters drop instead of
    aliasing a real slot."""
    (cap,) = live.shape
    rank = jnp.cumsum(~live) - 1
    slot = jnp.arange(cap, dtype=jnp.int32)
    target = jnp.where(~live & (rank < batch), rank, batch).astype(jnp.int32)
    return jnp.full((batch,), cap, jnp.int32).at[target].set(slot, mode="drop")


def add_alloc(
    cfg: ReplayConfig, state: ReplayState, items: Any, priorities: jax.Array,
    valid: jax.Array | None = None,
) -> ReplayState:
    """Add into *free* slots (leaf mass == 0) — DPG mode, paired with
    prioritized eviction which frees slots instead of a moving FIFO head.

    When the block is larger than the number of free slots, the overflow
    lanes are *dropped* (masked like invalid lanes) rather than spilling into
    live slots: eviction is the only thing allowed to free a live slot, so a
    full buffer sheds the overflow instead of silently clobbering experience
    (``total_added`` counts only lanes actually stored, so drops are visible
    as ``total_added`` falling behind the offered count).
    """
    (batch,) = priorities.shape
    if valid is None:
        valid = jnp.ones((batch,), bool)
    # Pack valid lanes first (stable, like add_fifo) so invalid lanes don't
    # waste free slots.
    order = jnp.argsort(~valid, stable=True)
    items = jax.tree.map(lambda x: x[order], items)
    priorities = priorities[order]
    valid = valid[order]

    live = sumtree.leaves(state.tree) > 0
    idx = free_slot_idx(live, batch)
    num_free = (~live).sum().astype(jnp.int32)
    offs = jnp.arange(batch, dtype=jnp.int32)
    # Lanes past the free-slot count would land on live slots: mask them out.
    applied = valid & (offs < num_free)
    storage, tree = _ingest(cfg, state, items, priorities, idx, applied)
    n_new = applied.sum().astype(jnp.int32)
    return ReplayState(
        storage=storage,
        tree=tree,
        write_pos=state.write_pos,
        size=jnp.minimum(state.size + n_new, cfg.capacity),
        total_added=state.total_added + n_new,
    )


def sample(cfg: ReplayConfig, state: ReplayState, rng: jax.Array, batch: int) -> SampleBatch:
    """Stratified proportional sampling + IS weights (Alg. 2 l.4; Appendix F).

    The descent emits each sampled slot's leaf mass alongside its index
    (fused in the Pallas backend), so no second tree gather is needed."""
    u = sumtree.stratified_uniforms(rng, batch, sumtree.total(state.tree))
    idx, leaf = sumtree.sample_with_mass(state.tree, u)
    items = jax.tree.map(lambda buf: buf[idx], state.storage)
    w = prio.importance_weights(leaf, sumtree.total(state.tree), state.size, cfg.beta)
    return SampleBatch(idx, items, w, leaf, sumtree.total(state.tree), state.size)


def set_priorities(
    cfg: ReplayConfig, state: ReplayState, idx: jax.Array, priorities: jax.Array
) -> ReplayState:
    """Learner writes back fresh |TD| priorities (Alg. 2 l.8).

    Dead slots (leaf mass 0) are left dead: with a decoupled learner the
    write-back may arrive after an eviction freed one of the sampled slots,
    and resurrecting it would break the ``size`` == live-leaf-count
    invariant. In the lockstep driver sampled slots are always live, so the
    gate is a no-op there.
    """
    old = sumtree.leaves(state.tree)[idx]
    new_leaf = prio.to_leaf(priorities, cfg.alpha)
    tree = sumtree.write(state.tree, idx, jnp.where(old > 0, new_leaf, 0.0))
    return state._replace(tree=tree)


def evict_fifo(cfg: ReplayConfig, state: ReplayState) -> ReplayState:
    """Remove the excess above the soft capacity en masse, oldest first (§4.1).

    A slot dies iff its FIFO age ``(slot - oldest) mod C`` is below the
    excess, so the kill mask is computed directly on the slot axis and the
    tree rebuilt from the masked leaves — no permuted index vector to
    materialize, no O(C) gather/scatter through it (and no O(C)-lane batch
    pushed through the incremental ``sumtree.write`` path, which is tuned
    for small batches)."""
    excess = jnp.maximum(state.size - cfg.soft_cap, 0)
    oldest = (state.write_pos - state.size) % cfg.capacity
    slot = jnp.arange(cfg.capacity, dtype=jnp.int32)
    age = (slot - oldest) % cfg.capacity
    kill = age < excess
    new_leaves = jnp.where(kill, 0.0, sumtree.leaves(state.tree))
    return state._replace(tree=sumtree.rebuild(new_leaves),
                          size=state.size - excess)


def evict_prioritized(
    cfg: ReplayConfig, state: ReplayState, rng: jax.Array, num: int
) -> ReplayState:
    """Sample ``num`` victims with probability ∝ p^alpha_evict and free them.

    Leaves hold p^alpha_sample, so the eviction mass is leaf^(alpha_evict /
    alpha_sample) on live slots. Sampling is with replacement (duplicates evict
    once), mirroring the paper's periodic batched eviction.
    """
    leaves = sumtree.leaves(state.tree)
    live = leaves > 0
    ratio = cfg.evict_alpha / cfg.alpha
    evict_mass = jnp.where(live, jnp.power(jnp.maximum(leaves, 1e-30), ratio), 0.0)
    etree = sumtree.rebuild(evict_mass)
    victims = sumtree.sample_stratified(etree, rng, num)
    old = leaves[victims]
    tree = sumtree.write(state.tree, victims, jnp.zeros((num,), leaves.dtype))
    # count distinct live victims actually freed
    mark = jnp.zeros((cfg.capacity,), jnp.int32).at[victims].set(1)
    freed = (mark * live.astype(jnp.int32)).sum()
    return state._replace(tree=tree, size=jnp.maximum(state.size - freed, 0))


def can_sample(cfg: ReplayConfig, state: ReplayState) -> jax.Array:
    """Learner gate: wait for min_fill items (paper: 50000 transitions)."""
    return (state.size >= cfg.min_fill) & (sumtree.total(state.tree) > 0)
