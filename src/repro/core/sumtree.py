"""Functional sum-tree for proportional prioritized sampling (Schaul et al. 2016).

The tree backs the Ape-X replay memory: leaves hold priorities ``p_k^alpha``
and internal nodes hold subtree sums, so sampling a key with probability
``p_k^alpha / sum_j p_j^alpha`` is a root-to-leaf descent.

Layout: for ``capacity`` C (power of two) the tree is a flat ``(2*C,)`` array.
Node 1 is the root, node ``i`` has children ``2i`` and ``2i+1``; leaf ``k``
lives at index ``C + k``. Index 0 is unused.

All operations are pure and batched. The two hot ops on the replay server both
have Pallas TPU kernels with the implementations here as their oracles / XLA
fallbacks:

* the sampling descent (``repro.kernels.sumtree_sample``) — inverse-CDF walk,
  optionally fused with the per-sample leaf-mass read;
* the batched write (``repro.kernels.sumtree_update``) — O(B * log C)
  incremental propagation, replacing the original O(C) full level-rebuild.

``write`` dispatches between them via a process-wide backend switch
(:func:`set_backend`): ``pallas`` on TPU, ``xla`` elsewhere, ``interpret``
to run the Pallas kernels under the interpreter (CPU CI). The incremental
XLA path (:func:`update`) is bit-identical to scatter + :func:`rebuild` by
construction: leaves are resolved with the same ``.at[idx].set`` scatter
(last writer wins under duplicates) and every touched parent is recomputed
as ``left + right`` — the identical fp32 operation ``rebuild``'s pairwise
level-sum performs — rather than patched with an (inexact) delta.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

__all__ = [
    "init",
    "capacity",
    "depth",
    "total",
    "leaves",
    "write",
    "write_rebuild",
    "update",
    "rebuild",
    "sample",
    "sample_with_mass",
    "sample_two_gather",
    "stratified_uniforms",
    "sample_stratified",
    "backend",
    "set_backend",
    "hot_backend",
]

# Process-wide backend for the hot ops (write / sample_with_mass):
#   "pallas"    — Pallas TPU kernels (compiled)
#   "interpret" — same kernels under the Pallas interpreter (CPU CI)
#   "xla"       — pure-jnp incremental paths (oracle / CPU fallback)
#   None        — auto: "pallas" on TPU, "xla" elsewhere
_BACKENDS = ("pallas", "interpret", "xla")
_backend: str | None = os.environ.get("REPRO_SUMTREE_BACKEND") or None

# The one-hot kernels hold (block_b, 2C)-shaped masks in VMEM, which is only
# viable for the small per-shard trees the replay fabric produces (the
# paper's 2M-transition / 256-shard geometry is a 16Ki-entry tree, ~64 KiB).
# The *auto* backend therefore only picks Pallas up to this leaf capacity
# and falls back to XLA above it; an explicit ``set_backend("pallas")`` (or
# env override) is honored unconditionally.
_PALLAS_AUTO_MAX_CAPACITY = 1 << 15


def backend() -> str:
    """The effective backend for the kernelized ops."""
    if _backend is not None:
        return _backend
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def set_backend(name: str | None) -> None:
    """Select the hot-op backend (``None`` restores auto-detection).

    The dispatch happens at trace time, so the switch only affects
    functions traced *afterwards* — already-jitted consumers (e.g. a live
    ``ReplayShard``'s ``ShardFns``) keep the backend that was active when
    they first compiled. Set the backend (or ``REPRO_SUMTREE_BACKEND``)
    before building shards/fabrics.
    """
    global _backend
    if name is not None and name not in _BACKENDS:
        raise ValueError(f"backend must be one of {_BACKENDS} or None, got {name!r}")
    _backend = name


def hot_backend(cap: int) -> str:
    """Backend for one hot-op call: the auto-selected Pallas path is gated
    on the tree being VMEM-small; explicit choices pass through. Shared by
    every kernelized op that holds whole-tree state in VMEM (``write``,
    ``sample_with_mass``, and ``repro.core.replay``'s fused ingest)."""
    bk = backend()
    if _backend is None and bk == "pallas" and cap > _PALLAS_AUTO_MAX_CAPACITY:
        return "xla"
    return bk


def _check_capacity(cap: int) -> None:
    if cap < 2 or (cap & (cap - 1)) != 0:
        raise ValueError(f"sum-tree capacity must be a power of two >= 2, got {cap}")


def init(cap: int, dtype=jnp.float32) -> jax.Array:
    """Return an empty tree of leaf capacity ``cap``."""
    _check_capacity(cap)
    return jnp.zeros((2 * cap,), dtype=dtype)


def capacity(tree: jax.Array) -> int:
    return tree.shape[0] // 2


def depth(tree: jax.Array) -> int:
    """Number of edges from root to leaf == log2(capacity)."""
    return (capacity(tree)).bit_length() - 1


def total(tree: jax.Array) -> jax.Array:
    """Total priority mass (root value)."""
    return tree[1]


def leaves(tree: jax.Array) -> jax.Array:
    return tree[capacity(tree):]


def rebuild(leaf_values: jax.Array) -> jax.Array:
    """Build a full tree from a ``(C,)`` leaf vector (C power of two)."""
    (cap,) = leaf_values.shape
    _check_capacity(cap)
    levels = [leaf_values]
    while levels[-1].shape[0] > 1:
        lv = levels[-1]
        levels.append(lv.reshape(-1, 2).sum(axis=1))
    # levels: [C, C/2, ..., 1]; tree[1:] = concat(reversed levels)
    flat = jnp.concatenate([lv for lv in reversed(levels)])
    return jnp.concatenate([jnp.zeros((1,), leaf_values.dtype), flat])


def write_rebuild(tree: jax.Array, idx: jax.Array, values: jax.Array) -> jax.Array:
    """Set ``leaves[idx] = values`` via a full O(C) level-rebuild.

    The original ``write`` implementation, kept as the oracle for the
    incremental paths: duplicate indices resolve scatter-style (last writer
    wins) before the exact rebuild, so internal sums are always consistent
    with leaves.
    """
    new_leaves = leaves(tree).at[idx].set(values.astype(tree.dtype), mode="drop")
    return rebuild(new_leaves)


def update(tree: jax.Array, idx: jax.Array, values: jax.Array) -> jax.Array:
    """Incremental batched write: O(B * log C) instead of ``rebuild``'s O(C).

    Leaves are set with the same ``.at[idx].set(mode="drop")`` scatter as
    :func:`write_rebuild` (so duplicate resolution is identical), then each
    level of ancestors is *recomputed* as ``tree[2p] + tree[2p + 1]`` — the
    same pairwise fp32 sum ``rebuild`` performs — and scattered back. Lanes
    sharing an ancestor all compute the identical value, so duplicate
    scatters at internal levels are benign, and writing ``left + right`` is
    always invariant-restoring even for lanes whose leaf write was dropped.
    Bit-identical to :func:`write_rebuild` on any tree whose internal nodes
    already satisfy the sum invariant.
    """
    cap = capacity(tree)
    # match the scatter's numpy-style index handling exactly: negatives in
    # [-C, -1] wrap, anything else out of [0, C) is dropped
    norm = jnp.where(idx < 0, idx + cap, idx).astype(jnp.int32)
    safe = jnp.clip(norm, 0, cap - 1)
    in_range = (norm >= 0) & (norm < cap)
    target = jnp.where(in_range, safe + cap, 2 * cap)  # OOB lanes: dropped
    tree = tree.at[target].set(values.astype(tree.dtype), mode="drop")

    # depth is static, so the walk unrolls: log2(C) tiny gather+scatter pairs
    # fuse into one XLA computation with no loop-carry overhead.
    node = safe + cap
    for _ in range(depth(tree)):
        node = node >> 1
        tree = tree.at[node].set(tree[2 * node] + tree[2 * node + 1])
    return tree


def write(tree: jax.Array, idx: jax.Array, values: jax.Array) -> jax.Array:
    """Set ``leaves[idx] = values`` and restore the sum invariant.

    Duplicate indices are resolved scatter-style (last writer wins); the
    propagation is incremental — O(B * log C) — on every backend (Pallas
    kernel on TPU, :func:`update` under XLA). Use :func:`write_rebuild` when
    the batch covers most of the tree (e.g. full-capacity rewrites).
    """
    bk = hot_backend(capacity(tree))
    if bk in ("pallas", "interpret"):
        from repro.kernels.sumtree_update.ops import sumtree_update
        return sumtree_update(tree, idx, values, interpret=(bk == "interpret"))
    return update(tree, idx, values)


def sample(tree: jax.Array, u: jax.Array) -> jax.Array:
    """Batched stochastic descent: map mass offsets ``u in [0, total)`` to leaf ids.

    For each offset the walk goes left when ``u < mass(left child)``, else
    subtracts the left mass and goes right — i.e. inverse-CDF sampling on the
    implicit prefix-sum of the leaves.
    """
    cap = capacity(tree)
    d = depth(tree)
    node = jnp.ones_like(u, dtype=jnp.int32)
    u = u.astype(tree.dtype)

    def body(_, carry):
        node, u = carry
        left = node * 2
        left_mass = tree[left]
        go_left = u < left_mass
        node = jnp.where(go_left, left, left + 1)
        u = jnp.where(go_left, u, u - left_mass)
        return node, u

    node, _ = jax.lax.fori_loop(0, d, body, (node, u))
    return jnp.clip(node - cap, 0, cap - 1)


def sample_two_gather(tree: jax.Array, u: jax.Array) -> tuple[jax.Array, jax.Array]:
    """The XLA form of the mass-emitting descent: plain :func:`sample`
    followed by a leaf gather. Two logical gathers, but XLA fuses them into
    one program with no kernel-launch boundary — on CPU/GPU hosts this is
    the fastest shape, so it is the form the ``xla`` backend keeps (the
    fused single-pass form only pays off where the descent kernel already
    holds the leaf level in VMEM)."""
    idx = sample(tree, u)
    return idx, leaves(tree)[idx]


def sample_with_mass(tree: jax.Array, u: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Fused descent: leaf ids *and* their masses ``p^alpha`` in one pass.

    ``replay.sample`` needs both. Backend-dispatched per path: the Pallas
    kernel emits the mass from the final descent level (no second tree
    gather); the ``xla`` backend keeps :func:`sample_two_gather`, whose
    descent + gather fuse into one XLA program anyway. The mass is bitwise
    ``leaves(tree)[idx]`` on every backend.
    """
    bk = hot_backend(capacity(tree))
    if bk in ("pallas", "interpret"):
        from repro.kernels.sumtree_sample.ops import sumtree_sample_with_mass
        return sumtree_sample_with_mass(tree, u, interpret=(bk == "interpret"))
    return sample_two_gather(tree, u)


def stratified_uniforms(rng: jax.Array, batch: int, total_mass: jax.Array) -> jax.Array:
    """Paper-faithful stratified offsets: one uniform per equal-mass stratum."""
    jitter = jax.random.uniform(rng, (batch,))
    u = (jnp.arange(batch, dtype=jnp.float32) + jitter) * (total_mass / batch)
    # guard the last stratum against fp overshoot of the root mass
    return jnp.minimum(u, total_mass * (1.0 - 1e-6))


def sample_stratified(tree: jax.Array, rng: jax.Array, batch: int) -> jax.Array:
    """Sample ``batch`` leaf ids with stratified proportional prioritization."""
    u = stratified_uniforms(rng, batch, total(tree))
    return sample(tree, u)
