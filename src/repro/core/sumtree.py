"""Functional sum-tree for proportional prioritized sampling (Schaul et al. 2016).

The tree backs the Ape-X replay memory: leaves hold priorities ``p_k^alpha``
and internal nodes hold subtree sums, so sampling a key with probability
``p_k^alpha / sum_j p_j^alpha`` is a root-to-leaf descent.

Layout: for ``capacity`` C (power of two) the tree is a flat ``(2*C,)`` array.
Node 1 is the root, node ``i`` has children ``2i`` and ``2i+1``; leaf ``k``
lives at index ``C + k``. Index 0 is unused.

All operations are pure and batched; writes rebuild the internal levels with
log2(C) reshape-sums, which is exact under duplicate indices and vectorizes
cleanly on TPU (the sampling descent — the hot op on the replay server — has a
Pallas kernel in ``repro.kernels.sumtree_sample``; the implementation here is
its oracle and the XLA fallback).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "init",
    "capacity",
    "depth",
    "total",
    "leaves",
    "write",
    "rebuild",
    "sample",
    "stratified_uniforms",
    "sample_stratified",
]


def _check_capacity(cap: int) -> None:
    if cap < 2 or (cap & (cap - 1)) != 0:
        raise ValueError(f"sum-tree capacity must be a power of two >= 2, got {cap}")


def init(cap: int, dtype=jnp.float32) -> jax.Array:
    """Return an empty tree of leaf capacity ``cap``."""
    _check_capacity(cap)
    return jnp.zeros((2 * cap,), dtype=dtype)


def capacity(tree: jax.Array) -> int:
    return tree.shape[0] // 2


def depth(tree: jax.Array) -> int:
    """Number of edges from root to leaf == log2(capacity)."""
    return (capacity(tree)).bit_length() - 1


def total(tree: jax.Array) -> jax.Array:
    """Total priority mass (root value)."""
    return tree[1]


def leaves(tree: jax.Array) -> jax.Array:
    return tree[capacity(tree):]


def rebuild(leaf_values: jax.Array) -> jax.Array:
    """Build a full tree from a ``(C,)`` leaf vector (C power of two)."""
    (cap,) = leaf_values.shape
    _check_capacity(cap)
    levels = [leaf_values]
    while levels[-1].shape[0] > 1:
        lv = levels[-1]
        levels.append(lv.reshape(-1, 2).sum(axis=1))
    # levels: [C, C/2, ..., 1]; tree[1:] = concat(reversed levels)
    flat = jnp.concatenate([lv for lv in reversed(levels)])
    return jnp.concatenate([jnp.zeros((1,), leaf_values.dtype), flat])


def write(tree: jax.Array, idx: jax.Array, values: jax.Array) -> jax.Array:
    """Set ``leaves[idx] = values`` and restore the sum invariant.

    Duplicate indices are resolved scatter-style (one writer wins) before the
    exact level-rebuild, so internal sums are always consistent with leaves.
    """
    cap = capacity(tree)
    new_leaves = leaves(tree).at[idx].set(values.astype(tree.dtype), mode="drop")
    return rebuild(new_leaves)


def sample(tree: jax.Array, u: jax.Array) -> jax.Array:
    """Batched stochastic descent: map mass offsets ``u in [0, total)`` to leaf ids.

    For each offset the walk goes left when ``u < mass(left child)``, else
    subtracts the left mass and goes right — i.e. inverse-CDF sampling on the
    implicit prefix-sum of the leaves.
    """
    cap = capacity(tree)
    d = depth(tree)
    node = jnp.ones_like(u, dtype=jnp.int32)
    u = u.astype(tree.dtype)

    def body(_, carry):
        node, u = carry
        left = node * 2
        left_mass = tree[left]
        go_left = u < left_mass
        node = jnp.where(go_left, left, left + 1)
        u = jnp.where(go_left, u, u - left_mass)
        return node, u

    node, _ = jax.lax.fori_loop(0, d, body, (node, u))
    return jnp.clip(node - cap, 0, cap - 1)


def stratified_uniforms(rng: jax.Array, batch: int, total_mass: jax.Array) -> jax.Array:
    """Paper-faithful stratified offsets: one uniform per equal-mass stratum."""
    jitter = jax.random.uniform(rng, (batch,))
    u = (jnp.arange(batch, dtype=jnp.float32) + jitter) * (total_mass / batch)
    # guard the last stratum against fp overshoot of the root mass
    return jnp.minimum(u, total_mass * (1.0 - 1e-6))


def sample_stratified(tree: jax.Array, rng: jax.Array, batch: int) -> jax.Array:
    """Sample ``batch`` leaf ids with stratified proportional prioritization."""
    u = stratified_uniforms(rng, batch, total(tree))
    return sample(tree, u)
