"""Pure-jnp oracle: the sliding-window fold from repro.core.nstep."""

from repro.core.nstep import from_trajectory as nstep_return_ref  # noqa: F401
