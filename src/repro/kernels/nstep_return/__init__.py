from repro.kernels.nstep_return import ops, ref  # noqa: F401
