"""Pallas TPU kernel: fused bulk n-step return construction (Appendix F).

Turns a rollout's (lanes, T) rewards/discounts into (lanes, T-n+1) n-step
returns and discount products in one VMEM pass:

    returns[t]    = sum_{k<n} R[t+k] * prod_{j<k} gamma[t+j]
    discount_n[t] = prod_{k<n} gamma[t+k]

The horizon n is small and static (paper: 3), so the window fold is fully
unrolled — n shifted elementwise FMAs on the VPU, no matmul. Lanes are tiled
by the grid; each block holds the full trajectory (T is a rollout chunk,
typically 10s-100s of steps, far under VMEM limits).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(reward_ref, discount_ref, returns_ref, disc_ref, *,
            n: int, window: int):
    r = reward_ref[...].astype(jnp.float32)        # (bl, T)
    g = discount_ref[...].astype(jnp.float32)
    acc = jnp.zeros((r.shape[0], window), jnp.float32)
    disc = jnp.ones((r.shape[0], window), jnp.float32)
    for k in range(n):                             # static unroll (n ~ 3)
        acc = acc + disc * jax.lax.dynamic_slice_in_dim(r, k, window, axis=1)
        disc = disc * jax.lax.dynamic_slice_in_dim(g, k, window, axis=1)
    returns_ref[...] = acc
    disc_ref[...] = disc


def nstep_return_pallas(reward: jax.Array, discount: jax.Array, n: int, *,
                        block_lanes: int = 128, interpret: bool = False):
    """reward/discount (lanes, T) -> (returns, discount_n) of (lanes, T-n+1)."""
    lanes, T = reward.shape
    if T < n:
        raise ValueError(f"T={T} < n={n}")
    window = T - n + 1
    block_lanes = min(block_lanes, lanes)
    pad = (-lanes) % block_lanes
    if pad:
        reward = jnp.pad(reward, ((0, pad), (0, 0)))
        discount = jnp.pad(discount, ((0, pad), (0, 0)))
    blocks = reward.shape[0] // block_lanes

    kernel = functools.partial(_kernel, n=n, window=window)
    returns, disc = pl.pallas_call(
        kernel,
        grid=(blocks,),
        in_specs=[
            pl.BlockSpec((block_lanes, T), lambda i: (i, 0)),
            pl.BlockSpec((block_lanes, T), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_lanes, window), lambda i: (i, 0)),
            pl.BlockSpec((block_lanes, window), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((blocks * block_lanes, window), jnp.float32),
            jax.ShapeDtypeStruct((blocks * block_lanes, window), jnp.float32),
        ],
        interpret=interpret,
    )(reward, discount)
    return returns[:lanes], disc[:lanes]
