"""Jit'd wrapper for the n-step return kernel."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.nstep_return.kernel import nstep_return_pallas


@partial(jax.jit, static_argnames=("n", "block_lanes", "interpret"))
def nstep_return(reward, discount, n: int, *, block_lanes: int = 128,
                 interpret: bool = False):
    """(lanes, T) rewards/discounts -> (returns, discount_n) of (lanes, T-n+1)."""
    return nstep_return_pallas(reward, discount, n, block_lanes=block_lanes,
                               interpret=interpret)
