"""Pure-jnp oracle for the flash attention kernel (materialized softmax)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers import attention_einsum


def flash_attention_ref(q, k, v, *, causal=True, window=None, q_offset=0,
                        scale=None):
    """q (B,Hq,Sq,D), k/v (B,Hkv,Sk,D/Dv) -> (B,Hq,Sq,Dv), computed with the
    reference materialized-scores attention (layers.attention_einsum operates
    in (B,S,H,D) layout; this wrapper keeps the kernel's (B,H,S,D))."""
    qs = jnp.swapaxes(q, 1, 2)
    ks = jnp.swapaxes(k, 1, 2)
    vs = jnp.swapaxes(v, 1, 2)
    out = attention_einsum(qs, ks, vs, causal=causal, window=window,
                           q_offset=q_offset, scale=scale)
    return jnp.swapaxes(out, 1, 2)
