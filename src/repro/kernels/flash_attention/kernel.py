"""Pallas TPU flash attention (causal, GQA, optional sliding window).

TPU-native tiling: grid = (batch, q_heads, q_blocks, kv_blocks) with the KV
block as the innermost (sequential on TPU) dimension; the online-softmax
running state (m, l, acc) lives in f32 VMEM scratch across KV iterations.
Block shapes default to (128, head_dim) — MXU-aligned on the contraction
dims (the 128 lanes of the systolic array).

GQA is resolved in the BlockSpec index maps: the K/V specs map query head
``h`` to KV head ``h // group`` so repeated KV heads are never materialized
in HBM or VMEM.

Causal / sliding-window structure short-circuits whole KV blocks with
``pl.when`` (a block runs only if any (q,k) pair in it is visible) —
out-of-range blocks cost a predicate, not a matmul. This is the structural
win over the XLA chunked path, which must execute every block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int | None,
            q_offset: int, block_q: int, block_k: int, kv_blocks: int,
            sk: int):
    qb = pl.program_id(2)
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Block-level visibility: skip fully-masked KV blocks.
    q_first = q_offset + qb * block_q
    q_last = q_first + block_q - 1
    k_first = kb * block_k
    k_last = k_first + block_k - 1
    visible = k_first < sk
    if causal:
        visible &= k_first <= q_last
    if window is not None:
        visible &= k_last > q_first - window

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        q_pos = q_first + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = k_first + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        ok = k_pos < sk
        if causal:
            ok &= q_pos >= k_pos
        if window is not None:
            ok &= q_pos - k_pos < window
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
        m_scr[...] = m_new

    @pl.when(kb == kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal=True, window=None, q_offset=0,
                         block_q=128, block_k=128, scale=None,
                         interpret=False):
    """q (B,Hq,Sq,D), k/v (B,Hkv,Sk,D / Dv) -> (B,Hq,Sq,Dv)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, Dv = v.shape
    if Hq % Hkv:
        raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
    group = Hq // Hkv
    scale = float(scale) if scale is not None else float(D) ** -0.5
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)

    q_pad = (-Sq) % block_q
    k_pad = (-Sk) % block_k
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, q_pad), (0, 0)))
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, k_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, k_pad), (0, 0)))
    q_blocks = q.shape[2] // block_q
    kv_blocks = k.shape[2] // block_k

    grid = (B, Hq, q_blocks, kv_blocks)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        q_offset=int(q_offset), block_q=block_q, block_k=block_k,
        kv_blocks=kv_blocks, sk=Sk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, block_k, Dv),
                         lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dv),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (B, Hq, q_blocks * block_q, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),        # running max
            pltpu.VMEM((block_q,), jnp.float32),        # running denom
            pltpu.VMEM((block_q, Dv), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
