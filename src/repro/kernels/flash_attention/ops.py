"""Jit'd public wrapper for the flash attention kernel.

Accepts the framework's (B, S, H, D) activation layout and dispatches to the
Pallas kernel ((B, H, S, D) internally). ``interpret=True`` runs the kernel
body in Python on CPU — the validation mode used by the test suite; on a real
TPU pass ``interpret=False``.

Differentiation: the Pallas call carries a ``custom_vjp`` whose forward is
the kernel and whose backward recomputes attention with the chunked XLA path
(flash-style recompute — no (Sq, Sk) residuals saved), so the kernel path is
trainable.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.models.layers import attention_chunked


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _flash(q, k, v, causal, window, q_offset, block_q, block_k, scale,
           interpret):
    out = flash_attention_bhsd(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k, scale=scale, interpret=interpret)
    return jnp.swapaxes(out, 1, 2)


def _flash_fwd(q, k, v, causal, window, q_offset, block_q, block_k, scale,
               interpret):
    return _flash(q, k, v, causal, window, q_offset, block_q, block_k, scale,
                  interpret), (q, k, v)


def _flash_bwd(causal, window, q_offset, block_q, block_k, scale, interpret,
               res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: attention_chunked(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            scale=scale, chunk=max(block_k, 128)),
        q, k, v)
    return vjp(g)


_flash.defvjp(_flash_fwd, _flash_bwd)


@partial(jax.jit, static_argnames=("causal", "window", "q_offset", "block_q",
                                   "block_k", "scale", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, q_offset=0,
                    block_q=128, block_k=128, scale=None, interpret=False):
    """q (B,Sq,Hq,D), k/v (B,Sk,Hkv,D/Dv) -> (B,Sq,Hq,Dv)."""
    return _flash(q, k, v, causal, window, q_offset, block_q, block_k, scale,
                  interpret)
