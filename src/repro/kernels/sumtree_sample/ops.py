"""Jit'd wrappers for the sum-tree sampling kernel."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.sumtree_sample.kernel import sumtree_sample_pallas


@partial(jax.jit, static_argnames=("block_b", "interpret"))
def sumtree_sample_with_mass(tree, u, *, block_b: int = 256,
                             interpret: bool = False):
    """tree (2C,), u (B,) in [0, total) -> ((B,) int32 leaf indices,
    (B,) f32 leaf masses) from one fused descent."""
    return sumtree_sample_pallas(tree, u, block_b=block_b, interpret=interpret)


@partial(jax.jit, static_argnames=("block_b", "interpret"))
def sumtree_sample(tree, u, *, block_b: int = 256, interpret: bool = False):
    """tree (2C,), u (B,) in [0, total) -> (B,) int32 leaf indices."""
    return sumtree_sample_pallas(tree, u, block_b=block_b,
                                 interpret=interpret)[0]
