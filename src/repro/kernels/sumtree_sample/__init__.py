from repro.kernels.sumtree_sample import ops, ref  # noqa: F401
