"""Pure-jnp oracle: the gather-based descent from repro.core.sumtree."""

from repro.core.sumtree import sample as sumtree_sample_ref  # noqa: F401
