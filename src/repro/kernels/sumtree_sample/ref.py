"""Pure-jnp oracles: the gather-based descent (and its fused-mass variant)
from repro.core.sumtree."""

from repro.core.sumtree import (  # noqa: F401
    sample as sumtree_sample_ref,
    sample_with_mass as sumtree_sample_with_mass_ref,
)
