"""Pallas TPU kernel: batched stochastic sum-tree descent (prioritized sampling).

The paper found the replay server CPU-bound and fixed it by batching all
requests (§Contention); on TPU the analogous hot op is the batched inverse-CDF
descent that turns a vector of mass offsets into leaf indices. Random gathers
don't vectorize on the TPU VPU, so the descent is re-cast as a *one-hot
select*: at every level the batch's current nodes are compared against a
lane-iota over the (VMEM-resident) tree and the left-child masses extracted
with a masked row-sum — an all-lanes operation instead of a serial gather.
A replay shard's tree is small (2 * capacity f32; 64 KiB at the paper's
2M/256-shard geometry), so the whole tree is a single VMEM block and only the
offset batch is tiled by the grid.

The kernel also emits each sampled leaf's mass ``p^alpha`` (one more one-hot
select at the final node), so ``replay.sample`` gets index and mass from one
fused pass instead of a descent plus a second leaf gather. The mass is
bitwise ``leaves(tree)[idx]``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(tree_ref, u_ref, idx_ref, mass_ref, *, depth: int, capacity: int,
            block_b: int):
    tree = tree_ref[...]                                    # (2C,) in VMEM
    u = u_ref[...].astype(jnp.float32)                      # (block_b,)
    node = jnp.ones((block_b,), jnp.int32)                  # root = 1
    lane = jax.lax.broadcasted_iota(jnp.int32, (block_b, 2 * capacity), 1)

    def level(_, carry):
        node, u = carry
        left = node * 2
        # one-hot select of tree[left] across the batch (VPU-friendly:
        # compare + masked row-sum instead of a serial gather)
        sel = (lane == left[:, None]).astype(jnp.float32)
        left_mass = jnp.sum(sel * tree[None, :], axis=1)
        go_left = u < left_mass
        node = jnp.where(go_left, left, left + 1)
        u = jnp.where(go_left, u, u - left_mass)
        return node, u

    node, _ = jax.lax.fori_loop(0, depth, level, (node, u))
    idx_ref[...] = jnp.clip(node - capacity, 0, capacity - 1)
    # fused leaf-mass read: one more one-hot select at the final node
    sel = (lane == (idx_ref[...] + capacity)[:, None]).astype(jnp.float32)
    mass_ref[...] = jnp.sum(sel * tree[None, :], axis=1)


def sumtree_sample_pallas(tree: jax.Array, u: jax.Array, *, block_b: int = 256,
                          interpret: bool = False
                          ) -> tuple[jax.Array, jax.Array]:
    """tree (2C,) f32 sum-tree, u (B,) mass offsets -> ((B,) int32 leaf ids,
    (B,) f32 leaf masses)."""
    (two_c,) = tree.shape
    capacity = two_c // 2
    depth = capacity.bit_length() - 1
    (B,) = u.shape
    block_b = min(block_b, B)
    pad = (-B) % block_b
    if pad:
        u = jnp.pad(u, (0, pad))
    blocks = u.shape[0] // block_b

    kernel = functools.partial(_kernel, depth=depth, capacity=capacity,
                               block_b=block_b)
    idx, mass = pl.pallas_call(
        kernel,
        grid=(blocks,),
        in_specs=[
            pl.BlockSpec((two_c,), lambda i: (0,)),         # whole tree in VMEM
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((blocks * block_b,), jnp.int32),
            jax.ShapeDtypeStruct((blocks * block_b,), jnp.float32),
        ],
        interpret=interpret,
    )(tree, u)
    return idx[:B], mass[:B]
