"""Jit'd wrapper for the incremental sum-tree update kernel."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.sumtree_update.kernel import sumtree_update_pallas


@partial(jax.jit, static_argnames=("block_b", "interpret"))
def sumtree_update(tree, idx, values, *, block_b: int = 128,
                   interpret: bool = False):
    """tree (2C,), idx (B,) leaf ids, values (B,) -> updated (2C,) tree."""
    return sumtree_update_pallas(tree, idx, values, block_b=block_b,
                                 interpret=interpret)
