"""Pure-jnp oracles: the incremental update and the full scatter + rebuild
from repro.core.sumtree (both produce bit-identical trees)."""

from repro.core.sumtree import (  # noqa: F401
    update as sumtree_update_ref,
    write_rebuild as sumtree_write_rebuild_ref,
)
