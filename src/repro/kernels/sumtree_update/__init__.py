from repro.kernels.sumtree_update import ops, ref  # noqa: F401
