"""Pallas TPU kernel: batched incremental sum-tree update (priority writes).

The replay server's mutation hot path: given B leaf indices and new values,
set the leaves and restore the sum invariant along all log2(C) ancestor
levels in one fused pass — O(B * log C) work instead of the O(C) full
level-rebuild the XLA path originally paid per write.

Like the descent kernel (``sumtree_sample``), random gathers/scatters don't
vectorize on the TPU VPU, so both directions are re-cast as one-hot
all-lanes ops against the VMEM-resident tree:

* *scatter-set* — a ``(B, 2C)`` equality mask against a lane iota selects
  each written node's column; ``jnp.where(any(mask), masked_sum, tree)``
  commits the batch in one shot. Duplicate writers are resolved to the
  *last* lane per node before the mask is built (matching ``.at[idx].set``
  scatter semantics), so each column has at most one writer.
* *gather* — child masses are read back with the same masked row-sum trick
  the descent kernel uses.

Each ancestor is recomputed as ``left + right`` (the exact op ``rebuild``'s
pairwise level-sum performs) rather than patched with a delta, which keeps
the kernel bit-identical to the XLA oracle ``repro.core.sumtree.update`` —
and transitively to scatter + ``rebuild``.

A replay shard's tree is small (2 * capacity f32; 64 KiB at the paper's
2M/256-shard geometry), so the whole tree lives in VMEM. The batch is tiled
by the grid; TPU grids run sequentially, so later blocks see earlier blocks'
writes (the output block is revisited), preserving cross-block
last-writer-wins order.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _last_writer(node: jax.Array, eligible: jax.Array, block_b: int) -> jax.Array:
    """Mask of lanes that are the highest-numbered eligible writer of their
    node value — the scatter's winner under duplicate indices."""
    row = jax.lax.broadcasted_iota(jnp.int32, (block_b, block_b), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (block_b, block_b), 1)
    shadowed = (node[None, :] == node[:, None]) & (col > row) & eligible[None, :]
    return eligible & ~jnp.any(shadowed, axis=1)


def _kernel(tree_ref, idx_ref, val_ref, out_ref, *, depth: int, capacity: int,
            block_b: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        out_ref[...] = tree_ref[...]

    tree = out_ref[...]                                     # (2C,) in VMEM
    idx = idx_ref[...]                                      # (block_b,)
    val = val_ref[...].astype(jnp.float32)
    lane = jax.lax.broadcasted_iota(jnp.int32, (block_b, 2 * capacity), 1)

    # numpy-style index handling, matching `.at[idx].set(mode="drop")`:
    # negatives in [-C, -1] wrap, anything else out of [0, C) is dropped
    idx = jnp.where(idx < 0, idx + capacity, idx)
    in_range = (idx >= 0) & (idx < capacity)
    node = jnp.clip(idx, 0, capacity - 1) + capacity

    # Leaf level: last in-range writer per leaf sets its value.
    wins = _last_writer(node, in_range, block_b)
    sel = (lane == node[:, None]) & wins[:, None]
    tree = jnp.where(jnp.any(sel, axis=0),
                     jnp.sum(jnp.where(sel, val[:, None], 0.0), axis=0),
                     tree)

    # Ancestor levels: recompute each touched parent as left + right. All
    # lanes sharing a parent compute the identical value, and even a lane
    # whose leaf write was dropped writes an invariant-restoring value — but
    # the one-hot sum needs exactly one writer per column, so a single
    # representative lane is elected per node.
    all_lanes = jnp.ones((block_b,), bool)

    def level(_, carry):
        tree, node = carry
        node = node >> 1
        lsel = (lane == (2 * node)[:, None]).astype(jnp.float32)
        rsel = (lane == (2 * node + 1)[:, None]).astype(jnp.float32)
        pval = (jnp.sum(lsel * tree[None, :], axis=1)
                + jnp.sum(rsel * tree[None, :], axis=1))
        rep = _last_writer(node, all_lanes, block_b)
        sel = (lane == node[:, None]) & rep[:, None]
        tree = jnp.where(jnp.any(sel, axis=0),
                         jnp.sum(jnp.where(sel, pval[:, None], 0.0), axis=0),
                         tree)
        return tree, node

    tree, _ = jax.lax.fori_loop(0, depth, level, (tree, node))
    out_ref[...] = tree


def sumtree_update_pallas(tree: jax.Array, idx: jax.Array, values: jax.Array,
                          *, block_b: int = 128,
                          interpret: bool = False) -> jax.Array:
    """tree (2C,) f32, idx (B,) int32 leaf ids, values (B,) -> updated tree.

    Index handling matches ``.at[idx].set(mode="drop")``: negatives in
    [-C, -1] wrap numpy-style, anything else out of [0, C) is dropped;
    duplicate indices resolve last-writer-wins.
    """
    (two_c,) = tree.shape
    capacity = two_c // 2
    depth = capacity.bit_length() - 1
    (B,) = idx.shape
    block_b = max(1, min(block_b, B)) if B else 1
    pad = (-B) % block_b if B else block_b
    if pad:
        # padding lanes carry an always-dropped index (>= C; negative
        # sentinels would wrap numpy-style and hit a real leaf)
        idx = jnp.pad(idx, (0, pad), constant_values=capacity)
        values = jnp.pad(values, (0, pad))
    blocks = idx.shape[0] // block_b

    kernel = functools.partial(_kernel, depth=depth, capacity=capacity,
                               block_b=block_b)
    out = pl.pallas_call(
        kernel,
        grid=(blocks,),
        in_specs=[
            pl.BlockSpec((two_c,), lambda i: (0,)),         # whole tree in VMEM
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((two_c,), lambda i: (0,)),   # revisited per block
        out_shape=jax.ShapeDtypeStruct((two_c,), tree.dtype),
        interpret=interpret,
    )(tree, idx.astype(jnp.int32), values.astype(tree.dtype))
    return out
