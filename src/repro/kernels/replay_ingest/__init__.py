from repro.kernels.replay_ingest import ops, ref  # noqa: F401
