"""Jit'd wrapper for the fused replay ingest kernel (pytree-aware)."""

from __future__ import annotations

from functools import partial

import jax

from repro.core.priority import PRIORITY_EXPONENT
from repro.kernels.replay_ingest.kernel import replay_ingest_pallas


@partial(jax.jit, static_argnames=("alpha", "block_b", "interpret"))
def replay_ingest(tree, storage, idx, priorities, applied, items, *,
                  alpha: float = PRIORITY_EXPONENT, block_b: int = 128,
                  interpret: bool = False):
    """tree (2C,), storage pytree of (C, ...), idx (B,) slot ids,
    priorities (B,) raw |TD|, applied (B,) lane mask, items pytree of
    (B, ...) -> (new_tree, new_storage)."""
    return replay_ingest_pallas(tree, storage, idx, priorities, applied,
                                items, alpha=alpha, block_b=block_b,
                                interpret=interpret)
