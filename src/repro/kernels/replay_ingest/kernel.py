"""Pallas TPU kernel: fused replay-block ingest (storage + leaf init + tree).

The replay server's *add* hot path, as one kernel. The XLA form of an ingest
is a chain of logical dispatches per block — priority init (``p^alpha``
leaf values), a masked scatter into every storage buffer, and the
incremental sum-tree write — each round-tripping the replay state through
HBM. This kernel consumes the already-computed slot indices (FIFO cursor
arithmetic or ``free_slot_idx``'s masked-cumsum compaction) plus the
``applied`` lane mask and performs everything else in one VMEM round-trip:

* *leaf values* — applied lanes take ``to_leaf(priority, alpha)`` (computed
  in-kernel with the exact ``repro.core.priority.to_leaf`` formula); masked
  lanes re-write their slot's *original* leaf, gathered from the input tree
  — the gather-then-scatter semantics of the XLA reference, where every
  lane's "old" value predates the whole batch.
* *leaf + ancestor repair* — identical machinery to the ``sumtree_update``
  kernel: last-writer-wins one-hot scatter at the leaf level, then each of
  the log2(C) ancestor levels recomputed as ``left + right`` via an elected
  representative lane, bit-identical to ``sumtree.update``.
* *storage scatter* — each storage buffer lives whole in VMEM as a
  ``(C, F)`` 2-D view; a serial walk over the block's lanes stores
  ``applied ? item_row : original_row`` at the lane's slot. In-order
  stores give last-writer-wins for duplicate slots; masked/out-of-range
  lanes are skipped (``pl.when``), matching ``.at[idx].set``'s
  drop-out-of-bounds scatter.

Index handling matches the XLA scatters exactly: negatives in [-C, -1]
wrap numpy-style, anything else outside [0, C) is dropped (``add_alloc``'s
overflow lanes arrive as index C, so a full buffer sheds them instead of
aliasing slot 0). TPU grids run sequentially and the outputs are revisited
whole-array blocks, so later batch tiles observe earlier tiles' writes —
cross-tile last-writer-wins — while the *gathers* of old values read the
untouched input refs, preserving reference semantics for every lane.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import priority as prio_lib


def _last_writer(node: jax.Array, eligible: jax.Array, block_b: int) -> jax.Array:
    """Mask of lanes that are the highest-numbered eligible writer of their
    node value — the scatter's winner under duplicate indices."""
    row = jax.lax.broadcasted_iota(jnp.int32, (block_b, block_b), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (block_b, block_b), 1)
    shadowed = (node[None, :] == node[:, None]) & (col > row) & eligible[None, :]
    return eligible & ~jnp.any(shadowed, axis=1)


def _kernel(*refs, depth: int, capacity: int, block_b: int, n_bufs: int,
            alpha: float):
    tree_ref = refs[0]
    idx_ref, prio_ref, app_ref = refs[1:4]
    buf_in = refs[4:4 + n_bufs]
    item_in = refs[4 + n_bufs:4 + 2 * n_bufs]
    out_tree = refs[4 + 2 * n_bufs]
    buf_out = refs[5 + 2 * n_bufs:]

    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        out_tree[...] = tree_ref[...]
        for src, dst in zip(buf_in, buf_out):
            dst[...] = src[...]

    idx = idx_ref[...]                                      # (block_b,)
    applied = app_ref[...] != 0
    pr = prio_ref[...].astype(jnp.float32)

    # numpy-style index handling, matching `.at[idx].set(mode="drop")`:
    # negatives in [-C, -1] wrap, anything else out of [0, C) is dropped
    idx = jnp.where(idx < 0, idx + capacity, idx)
    in_range = (idx >= 0) & (idx < capacity)
    slot = jnp.clip(idx, 0, capacity - 1)
    node = slot + capacity
    lane = jax.lax.broadcasted_iota(jnp.int32, (block_b, 2 * capacity), 1)

    # Leaf values: applied lanes initialize to p^alpha; masked lanes re-write
    # the slot's *original* leaf, gathered from the input tree (which no grid
    # step mutates — the reference's gather-all-then-scatter semantics).
    gsel = (lane == node[:, None]).astype(jnp.float32)
    old_leaf = jnp.sum(gsel * tree_ref[...][None, :], axis=1)
    val = jnp.where(applied, prio_lib.to_leaf(pr, alpha), old_leaf)

    # Storage: serial walk over lanes — in-order stores are last-writer-wins
    # under duplicate slots, and skipping out-of-range lanes is the scatter's
    # drop. Old rows come from the (unmutated) input buffers.
    def lane_body(b, carry):
        @pl.when(in_range[b])
        def _():
            t = slot[b]
            for src, dst, itm in zip(buf_in, buf_out, item_in):
                old = pl.load(src, (pl.ds(t, 1), slice(None)))
                new = pl.load(itm, (pl.ds(b, 1), slice(None)))
                pl.store(dst, (pl.ds(t, 1), slice(None)),
                         jnp.where(applied[b], new, old))
        return carry

    jax.lax.fori_loop(0, block_b, lane_body, 0)

    # Tree repair on the *output* tree: leaf-level last-writer-wins scatter,
    # then each ancestor level recomputed as left + right via an elected
    # representative lane — the sumtree_update kernel's machinery verbatim.
    tree = out_tree[...]
    wins = _last_writer(node, in_range, block_b)
    sel = (lane == node[:, None]) & wins[:, None]
    tree = jnp.where(jnp.any(sel, axis=0),
                     jnp.sum(jnp.where(sel, val[:, None], 0.0), axis=0),
                     tree)

    all_lanes = jnp.ones((block_b,), bool)

    def level(_, carry):
        tree, node = carry
        node = node >> 1
        lsel = (lane == (2 * node)[:, None]).astype(jnp.float32)
        rsel = (lane == (2 * node + 1)[:, None]).astype(jnp.float32)
        pval = (jnp.sum(lsel * tree[None, :], axis=1)
                + jnp.sum(rsel * tree[None, :], axis=1))
        rep = _last_writer(node, all_lanes, block_b)
        sel = (lane == node[:, None]) & rep[:, None]
        tree = jnp.where(jnp.any(sel, axis=0),
                         jnp.sum(jnp.where(sel, pval[:, None], 0.0), axis=0),
                         tree)
        return tree, node

    tree, _ = jax.lax.fori_loop(0, depth, level, (tree, node))
    out_tree[...] = tree


def replay_ingest_pallas(tree: jax.Array, storage, idx: jax.Array,
                         priorities: jax.Array, applied: jax.Array, items,
                         *, alpha: float = prio_lib.PRIORITY_EXPONENT,
                         block_b: int = 128,
                         interpret: bool = False):
    """Fused ingest of one packed transition block.

    ``tree`` (2C,) f32; ``storage`` a pytree of (C, ...) buffers; ``idx``
    (B,) int32 slot ids; ``priorities`` (B,) raw |TD|; ``applied`` (B,)
    lane mask (False lanes re-write their slot's old leaf/row — a no-op
    for distinct slots); ``items`` a pytree of (B, ...) rows matching
    ``storage``. Returns ``(new_tree, new_storage)``, bit-identical to the
    three-dispatch reference ``repro.kernels.replay_ingest.ref``.
    """
    (two_c,) = tree.shape
    capacity = two_c // 2
    depth = capacity.bit_length() - 1
    flat_bufs, treedef = jax.tree.flatten(storage)
    flat_items = treedef.flatten_up_to(items)

    (B,) = idx.shape
    block_b = max(1, min(block_b, B)) if B else 1
    pad = (-B) % block_b if B else block_b
    idx = idx.astype(jnp.int32)
    # bool refs are fragile on TPU; carry the mask as int32 lanes
    applied = applied.astype(jnp.int32)
    priorities = priorities.astype(jnp.float32)
    # 2-D (rows, features) views: scalar leaves get a unit feature axis,
    # higher-rank leaves flatten their trailing axes; items are pre-cast to
    # the buffer dtype (the reference's `x.astype(buf.dtype)`).
    shapes = [b.shape for b in flat_bufs]
    bufs2d = [b.reshape(capacity, -1) for b in flat_bufs]
    items2d = [x.astype(b.dtype).reshape(x.shape[0], -1)
               for x, b in zip(flat_items, flat_bufs)]
    if pad:
        # padding lanes carry an always-dropped index (>= C; negative
        # sentinels would wrap numpy-style and hit a real leaf)
        idx = jnp.pad(idx, (0, pad), constant_values=capacity)
        priorities = jnp.pad(priorities, (0, pad))
        applied = jnp.pad(applied, (0, pad))
        items2d = [jnp.pad(x, ((0, pad), (0, 0))) for x in items2d]
    blocks = idx.shape[0] // block_b

    kernel = functools.partial(_kernel, depth=depth, capacity=capacity,
                               block_b=block_b, n_bufs=len(bufs2d),
                               alpha=alpha)
    lane_spec = pl.BlockSpec((block_b,), lambda i: (i,))
    outs = pl.pallas_call(
        kernel,
        grid=(blocks,),
        in_specs=(
            [pl.BlockSpec((two_c,), lambda i: (0,))]        # whole tree
            + [lane_spec, lane_spec, lane_spec]
            + [pl.BlockSpec(b.shape, lambda i: (0, 0)) for b in bufs2d]
            + [pl.BlockSpec((block_b, x.shape[1]), lambda i: (i, 0))
               for x in items2d]),
        out_specs=(
            [pl.BlockSpec((two_c,), lambda i: (0,))]        # revisited
            + [pl.BlockSpec(b.shape, lambda i: (0, 0)) for b in bufs2d]),
        out_shape=(
            [jax.ShapeDtypeStruct((two_c,), tree.dtype)]
            + [jax.ShapeDtypeStruct(b.shape, b.dtype) for b in bufs2d]),
        interpret=interpret,
    )(tree, idx, priorities, applied, *bufs2d, *items2d)
    new_tree = outs[0]
    new_bufs = [o.reshape(s) for o, s in zip(outs[1:], shapes)]
    return new_tree, jax.tree.unflatten(treedef, new_bufs)
