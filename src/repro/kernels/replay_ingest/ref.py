"""Pure-jnp oracle: the pre-fusion three-dispatch ingest chain.

Exactly the ops ``repro.core.replay.add_fifo``/``add_alloc`` issued before
the fused kernel existed — leaf init (``to_leaf`` under the ``applied``
mask), a masked gather-then-scatter per storage buffer, and the incremental
sum-tree write — in reference (XLA) form. The fused kernel must be
bit-identical to this on any input, including duplicate slots, out-of-range
(overflow) lanes, and masked lanes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import priority as prio
from repro.core import sumtree


def replay_ingest_ref(tree, storage, idx, priorities, applied, items, *,
                      alpha: float = prio.PRIORITY_EXPONENT):
    """Three logical dispatches: leaf values, storage scatter, tree write.

    All "old" values (masked lanes' leaves and rows) are gathered from the
    *input* state before any scatter lands, and duplicate slots resolve
    last-writer-wins — the semantics the fused kernel reproduces.
    """
    leaf = jnp.where(applied, prio.to_leaf(priorities, alpha),
                     sumtree.leaves(tree)[idx])
    new_storage = jax.tree.map(
        lambda buf, x: buf.at[idx].set(
            jnp.where(jnp.expand_dims(applied, tuple(range(1, x.ndim))),
                      x.astype(buf.dtype), buf[idx])),
        storage, items)
    new_tree = sumtree.update(tree, idx, leaf)
    return new_tree, new_storage
