"""The paper's technique as an LLM data-selection layer (paper §6): train a
reduced llama3.2-style model with prioritized *sequence* replay on the
synthetic Markov-mixture corpus, and show the selection signal — hard
(high-entropy) documents get sampled more than easy ones.

  PYTHONPATH=src python examples/train_llm_prioritized.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import replay as replay_lib, sequence_replay as seqrep, sumtree
from repro.data import pipeline as data_lib
from repro.models import registry, transformer
from repro.optim import optimizers as optim


def main():
    seq_len, batch = 64, 8
    cfg = registry.get_config("llama3.2-1b").reduced(d_model=128, vocab=512)
    params = transformer.init(cfg, jax.random.key(0))
    optimizer = optim.adamw(1e-3)
    scfg = seqrep.SeqReplayConfig(
        replay=replay_lib.ReplayConfig(capacity=512, min_fill=batch),
        seq_len=seq_len, batch_size=batch, ingest_batch=batch,
        param_sync_period=4, learner_steps_per_round=2)
    pcfg = data_lib.PipelineConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                                   batch_size=batch)
    apply_fn = lambda p, toks: transformer.apply(p, toks, cfg=cfg)
    state = seqrep.init_state(scfg, params, optimizer, jax.random.key(1))

    @jax.jit
    def round_step(state, step):
        b = data_lib.make_batch(pcfg, jax.random.key(7), step)
        return seqrep.round_step(scfg, apply_fn, optimizer, state,
                                 b["tokens"], b["labels"])

    for it in range(60):
        state, m = round_step(state, it)
        if (it + 1) % 10 == 0:
            print(f"round {it+1:3d}  loss={float(m['loss']):.4f}  "
                  f"mean_priority={float(m['mean_priority']):.4f}  "
                  f"replay={int(state.replay.size)}")

    # Show the selection signal: priority mass vs document diversity.
    leaves = np.asarray(sumtree.leaves(state.replay.tree))
    toks = np.asarray(state.replay.storage["tokens"])
    live = leaves > 0
    uniq = np.array([len(set(r.tolist())) for r in toks])
    lo = leaves[live & (uniq < np.median(uniq[live]))].mean()
    hi = leaves[live & (uniq >= np.median(uniq[live]))].mean()
    print(f"\npriority mass: low-diversity docs {lo:.4f} vs "
          f"high-diversity docs {hi:.4f}")
    print("prioritized replay focuses the learner on the harder documents."
          if hi > lo else "(signal not yet separated at this tiny scale)")


if __name__ == "__main__":
    main()
