"""Quickstart: the Ape-X loop in ~40 lines against the public API.

Builds the reduced Ape-X DQN preset (dueling double-DQN, eps-ladder actors,
sharded prioritized replay with actor-computed initial priorities) and trains
on the sparse-reward ChainWorld for a couple hundred iterations on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.configs import apex_dqn
from repro.core import apex


def main():
    preset = apex_dqn.reduced()          # paper structure, toy scale
    optimizer = preset.make_optimizer()  # centered RMSProp (Appendix C)
    init_fn, step_fn = apex.make_train_fn(
        preset.apex, preset.env, preset.agent, optimizer)

    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from train_apex_dqn import evaluate_greedy

    state = init_fn(jax.random.key(0))
    evals = []
    for it in range(200):
        state, metrics = step_fn(state)
        if (it + 1) % 25 == 0:
            score = evaluate_greedy(preset, state.params)
            evals.append(score)
            print(f"iter {it+1:4d}  frames={int(metrics['frames']):7d}  "
                  f"replay={int(metrics['replay_size']):6d}  "
                  f"greedy_eval={score:7.3f}  "
                  f"loss={float(metrics['loss']):.5f}")

    print(f"\ngreedy evaluation: first {evals[0]:.3f} -> best {max(evals):.3f} "
          f"({'improved' if max(evals) > evals[0] else 'no improvement'})")


if __name__ == "__main__":
    main()
