"""End-to-end driver: train Ape-X DQN for a few hundred iterations with
checkpointing, periodic evaluation with a greedy policy, and a resume path —
the full production loop at CPU scale (paper Fig. 2 workflow).

  PYTHONPATH=src python examples/train_apex_dqn.py [--iterations 300]

``--runtime async`` trains through the decoupled actor/learner runtime
instead (actors + replay fabric + learner on separate threads, paper Fig. 1)
and then runs the same greedy evaluation on the learned parameters;
``--replay-shards`` shards the replay fabric and ``--inference-batching``
shares one batched act dispatch across the actor threads:

  PYTHONPATH=src python examples/train_apex_dqn.py --runtime async \
      --iterations 300 --actor-threads 2 --replay-shards 2 \
      --inference-batching
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs import apex_dqn
from repro.core import apex
from repro.envs.synthetic import batch_reset, batch_step
from repro.launch.train import run_apex_async


def evaluate_greedy(preset, params, episodes=8, seed=123):
    """Paper evaluation regime: the greediest policy, separate env instances."""
    env, agent = preset.env, preset.agent
    states, obs = batch_reset(env, jax.random.key(seed), episodes)
    total = jnp.zeros((episodes,))
    done_once = jnp.zeros((episodes,), bool)
    eps = jnp.zeros((episodes,))  # greedy
    rng = jax.random.key(seed + 1)
    for _ in range(env.max_steps + 1):
        rng, a_rng = jax.random.split(rng)
        a, _ = agent.act(params, a_rng, obs, eps)
        states, out = batch_step(env, states, a)
        total = total + out.reward * (~done_once)
        done_once = done_once | (out.discount == 0)
        obs = out.obs
    return float(total.mean())


def main_async(args):
    """Decoupled-runtime path: train via the shared launcher helper (actor /
    replay-service / learner threads + stats report + final checkpoint),
    then evaluate the learned greedy policy."""
    preset = apex_dqn.reduced()
    os.makedirs(args.ckpt_dir, exist_ok=True)
    res = run_apex_async(preset, args.iterations, args.actor_threads,
                         args.ckpt_dir, args.replay_shards,
                         args.inference_batching, args.actor_procs,
                         args.learn_batches,
                         sample_staging=args.sample_staging)
    final = evaluate_greedy(preset, res.learner.params, episodes=16)
    print(f"\nfinal greedy evaluation over 16 episodes: {final:.3f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iterations", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/apex_dqn_ckpts")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--runtime", choices=("sync", "async"), default="sync")
    ap.add_argument("--actor-threads", type=int, default=1)
    ap.add_argument("--actor-procs", type=int, default=0,
                    help="remote actor OS processes via the replay gateway")
    ap.add_argument("--replay-shards", type=int, default=1)
    ap.add_argument("--inference-batching", action="store_true")
    ap.add_argument("--learn-batches", type=int, default=1,
                    help="batches per jitted learner call (lax.scan)")
    ap.add_argument("--sample-staging", action="store_true",
                    help="double-buffer the learner's sample path through "
                         "async device puts (see repro.runtime.sources)")
    args = ap.parse_args()

    if args.runtime == "async":
        if args.resume:
            ap.error("--resume is not supported with --runtime async")
        return main_async(args)

    preset = apex_dqn.reduced()
    optimizer = preset.make_optimizer()
    init_fn, step_fn = apex.make_train_fn(
        preset.apex, preset.env, preset.agent, optimizer)
    state = init_fn(jax.random.key(0))

    if args.resume:
        latest = ckpt.latest(args.ckpt_dir)
        if latest:
            saved = ckpt.restore(latest, {"params": state.params,
                                          "target_params": state.target_params,
                                          "opt_state": state.opt_state})
            state = state._replace(**saved)
            print(f"resumed from {latest}")

    t0 = time.time()
    for it in range(args.iterations):
        state, metrics = step_fn(state)
        if (it + 1) % 50 == 0:
            score = evaluate_greedy(preset, state.params)
            fps = float(state.frames) / (time.time() - t0)
            print(f"iter {it+1:4d}  fps={fps:7.0f}  greedy_eval={score:7.3f}  "
                  f"loss={float(metrics['loss']):.5f}  "
                  f"replay={int(metrics['replay_size'])}")
            os.makedirs(args.ckpt_dir, exist_ok=True)
            ckpt.save(os.path.join(args.ckpt_dir, f"ckpt_{it+1}.npz"),
                      {"params": state.params,
                       "target_params": state.target_params,
                       "opt_state": state.opt_state}, step=it + 1)

    final = evaluate_greedy(preset, state.params, episodes=16)
    print(f"\nfinal greedy evaluation over 16 episodes: {final:.3f}")


if __name__ == "__main__":
    main()
