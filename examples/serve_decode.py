"""Serving example: batched greedy decode with prefill + one-token steps —
the exact step the decode dry-runs lower at 32k/500k, at CPU scale, for an
attention arch, an SSM (RWKV6), and the MLA latent-cache arch.

  PYTHONPATH=src python examples/serve_decode.py
"""

from repro.launch.serve import serve


def main():
    for arch in ("llama3.2-1b", "rwkv6-1.6b", "deepseek-v2-236b"):
        serve(arch, batch=2, prompt_len=12, new_tokens=12, reduced=True)


if __name__ == "__main__":
    main()
